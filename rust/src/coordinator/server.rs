//! Threaded serving front-end: a **slot-aware** request router feeding
//! one or more scheduler workers over channels (std threads — the
//! vendored crate set has no tokio; see DESIGN.md §4). Each worker runs
//! the continuous-batching tick loop ([`Scheduler::tick`]) over its own
//! shard of the sharded state arena: one mixed engine call per tick,
//! decode rows plus prefill chunks under the policy's token budget.
//!
//! The router is the paper's leader: it places new requests on the
//! least-loaded shard ([`ShardMap`]) and — the sharded design's payoff
//! — **migrates in-flight requests between workers** over the same
//! channels, splicing their resident state rows from one shard's arena
//! into another's ([`Scheduler::detach`] → [`Scheduler::attach`]). A
//! migration is one counted `state_bytes_per_seq` transfer
//! (`bytes_migrated`), never a re-prefill; [`Server::rebalance`] plans
//! moves under the [`RouterPolicy`] hysteresis so balanced or
//! alternating load never thrashes state between workers.
//!
//! **Sessions** ([`Server::submit_session`] / [`Server::fork_session`])
//! pin each conversation to one shard, whose scheduler keeps a
//! snapshot cache of completed turns: a follow-up prompt extending the
//! previous turn attaches the cached state row and prefills only its
//! new tokens.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::obs::{TraceEvent, TraceRecord};
use crate::planner::{Planner, PlanSpec};
use crate::runtime::engine::Executor;
use crate::runtime::EngineCaps;

use super::batcher::BatchPolicy;
use super::metrics::{LatencyReport, TrafficSnapshot};
use super::request::{Request, Response};
use super::scheduler::Scheduler;
use super::shard::{
    Migration, MigrationMode, MigrationOutcome, MigrationPacket, RouterPolicy, ShardMap,
    WorkerLoad,
};

/// A successful detach reply: the transfer packet plus the response
/// sink, which follows the request to its new worker.
type DetachReply = (Box<MigrationPacket>, Sender<Response>);

/// One salvaged in-flight request leaving a dead worker: the transfer
/// packet (state-carrying for untouched rows, token-only for suspect
/// ones) paired with its response sink.
type SalvageEntry = (Box<MigrationPacket>, Sender<Response>);

/// Worker → supervisor notifications, delivered on a dedicated channel
/// (never mixed with completions: a `Down` carries sinks).
enum WorkerEvent {
    /// A worker died — engine fault mid-serve or construction failure.
    /// `salvage` holds every in-flight request it could export;
    /// `generation` guards against a stale tombstone retiring a
    /// respawned healthy worker.
    Down {
        shard: usize,
        generation: u64,
        salvage: Vec<SalvageEntry>,
        /// The dead worker's trace ring, drained *before* salvage
        /// consumed its scheduler (plus one `Salvaged` record per
        /// exported flight) — a worker death loses no trace records.
        trace: Vec<TraceRecord>,
        /// Its latency histograms, likewise captured before salvage so
        /// server-wide percentiles still cover completions it served.
        latency: LatencyReport,
        /// Its traffic counters at death (gauges zeroed: the state and
        /// cache they measured are gone). Folded into
        /// [`Server::traffic`] so a worker death never makes the
        /// server-wide counters go backwards — and so the trace still
        /// reconciles against them exactly.
        traffic: TrafficSnapshot,
    },
    /// A submit that reached a dead worker's mailbox; the supervisor
    /// re-routes it to a live shard (or fails it terminally).
    Orphan {
        req: Request,
        session: Option<u64>,
        sink: Sender<Response>,
    },
}

/// Supervision counters, accumulated by the [`Server`] across worker
/// failures. All deterministic under a deterministic fault plan.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResilienceStats {
    /// Worker deaths the supervisor retired (per generation).
    pub workers_down: u64,
    /// Respawns performed within the restart budget.
    pub worker_restarts: u64,
    /// Salvaged flights re-attached with their state (one counted copy,
    /// no replay).
    pub requests_salvaged: u64,
    /// Salvaged flights whose state was suspect (or absent): re-routed
    /// as token-only re-prefills.
    pub requests_reprefilled_on_fault: u64,
    /// Requests terminally failed (retry budget exhausted, no healthy
    /// worker, or unroutable submit). Each sent exactly one error
    /// [`Response`] to its sink.
    pub requests_failed: u64,
}

/// A retained, re-invocable engine factory: respawning a shard calls it
/// again on the replacement worker's thread.
type Spawner = Box<dyn FnMut(u64) -> Worker + Send>;

enum Msg {
    Submit(Request, Sender<Response>),
    /// Session-tagged submit: the worker consults its snapshot cache
    /// and, on a hit, prefills only the tokens after the cached
    /// history.
    SubmitSession(Request, u64, Sender<Response>),
    /// Copy-on-write session fork on the worker owning the parent.
    Fork(u64, u64, Sender<bool>),
    /// Replace every worker's snapshot-cache byte budget.
    SnapshotBudget(u64),
    Report(Sender<String>),
    Traffic(Sender<TrafficSnapshot>),
    /// Drain the worker's lifecycle-trace ring.
    Trace(Sender<Vec<TraceRecord>>),
    /// Copy of the worker's mergeable latency histograms.
    Latency(Sender<LatencyReport>),
    Caps(Sender<EngineCaps>),
    Load(Sender<WorkerLoad>),
    Detach(u64, Sender<Option<DetachReply>>),
    Attach(Box<MigrationPacket>, Sender<Response>, MigrationMode),
    /// Gauge sync: resident state bytes on every *other* shard.
    RemoteResident(u64),
    Shutdown,
}

/// One worker: a scheduler on its own thread, owning one arena shard.
struct Worker {
    tx: Sender<Msg>,
    handle: JoinHandle<()>,
    /// Incarnation counter for this shard (0 for the original worker,
    /// +1 per respawn) — matched against `WorkerEvent::Down` so stale
    /// death notices from a replaced tombstone are ignored.
    generation: u64,
}

/// The router/server: owns the workers, routes new requests by
/// least-load and migrates in-flight ones by moving their state.
pub struct Server {
    workers: Vec<Worker>,
    /// Retained engine factories, one per shard, so a dead worker can
    /// be respawned within the restart budget.
    spawners: Vec<Spawner>,
    /// Respawns consumed per shard (bounded by `max_restarts`).
    restarts: Vec<u32>,
    /// Join handles of replaced (dead) workers, joined at shutdown.
    retired: Vec<JoinHandle<()>>,
    shards: ShardMap,
    router: RouterPolicy,
    mode: MigrationMode,
    /// Completion notifications from the workers (request ids), drained
    /// lazily so the router's tracked load stays honest.
    done_rx: Receiver<u64>,
    /// Supervision events (worker deaths with salvage, orphaned
    /// submits), drained by [`Server::supervise`].
    event_rx: Receiver<WorkerEvent>,
    /// Session id → shard. Snapshot caches are per-worker state, so a
    /// session is pinned to the shard that served its first turn —
    /// every follow-up (and fork child) routes there, which is what
    /// guarantees the cache lookup can hit.
    sessions: BTreeMap<u64, usize>,
    /// Respawn budget per shard; 0 disables respawn entirely.
    max_restarts: u32,
    /// Per-request fault-replay budget: a flight re-routed more than
    /// this many times fails terminally instead of looping.
    max_replays: u32,
    stats: ResilienceStats,
    /// Router-scoped lifecycle records (`Routed` placements, terminal
    /// `Failed`s) — the router has no tick clock, so these stamp tick 0.
    router_trace: Vec<TraceRecord>,
    /// Trace records recovered from dead workers (shipped in their
    /// `Down` events), drained by [`Server::trace`].
    dead_trace: Vec<TraceRecord>,
    /// Latency histograms recovered from dead workers, merged into
    /// [`Server::latency`].
    dead_latency: LatencyReport,
    /// Traffic counters recovered from dead workers, folded into
    /// [`Server::traffic`].
    dead_traffic: TrafficSnapshot,
    /// Front-end admission accounting (shed + per-class counters).
    /// Lives on the router — workers never see a shed request — and is
    /// folded into [`Server::traffic`] exactly like dead-worker
    /// counters, so the lifecycle trace still reconciles.
    frontend_traffic: TrafficSnapshot,
}

impl Server {
    /// Start with one worker per engine *factory*. Each worker
    /// constructs its engine on its own thread (PJRT handles are not
    /// `Send`). Multiple workers model the paper's leader/worker split:
    /// the router is the leader, each PJRT engine a worker owning one
    /// shard of the state arena.
    pub fn start<E, F>(factories: Vec<F>, policy: BatchPolicy) -> Server
    where
        E: Executor,
        F: FnMut() -> anyhow::Result<E> + Send + 'static,
    {
        Server::start_planned(factories, policy, PlanSpec::default())
    }

    /// Start with an explicit plan-selection policy (each worker gets
    /// its own [`Planner`] built from the spec — plan caches and dwell
    /// state are per-worker, like the engine itself).
    ///
    /// Factories are `FnMut` and **retained**: when a worker dies (tick
    /// fault or construction failure) the supervisor may call the
    /// shard's factory again to respawn it, up to
    /// [`Server::set_max_restarts`].
    pub fn start_planned<E, F>(factories: Vec<F>, policy: BatchPolicy, spec: PlanSpec) -> Server
    where
        E: Executor,
        F: FnMut() -> anyhow::Result<E> + Send + 'static,
    {
        let n_shards = factories.len();
        let (done_tx, done_rx) = channel();
        let (event_tx, event_rx) = channel();
        let mut workers = Vec::with_capacity(n_shards);
        let mut spawners: Vec<Spawner> = Vec::with_capacity(n_shards);
        for (shard, factory) in factories.into_iter().enumerate() {
            // The factory crosses into each incarnation's thread (the
            // engine must be constructed there — PJRT handles are not
            // `Send`) and must come back for the next respawn, hence
            // the shared cell.
            let factory = std::sync::Arc::new(std::sync::Mutex::new(factory));
            let policy = policy.clone();
            let spec = spec.clone();
            let done = done_tx.clone();
            let events = event_tx.clone();
            let mut spawn: Spawner = Box::new(move |generation: u64| {
                let (tx, rx) = channel::<Msg>();
                let factory = std::sync::Arc::clone(&factory);
                let pol = policy.clone();
                let sp = spec.clone();
                let done = done.clone();
                let events = events.clone();
                let handle = std::thread::spawn(move || {
                    let built = {
                        let mut f = factory.lock().expect("engine factory mutex");
                        f()
                    };
                    match built {
                        Ok(engine) => {
                            worker_loop(engine, pol, sp, shard, generation, rx, done, events)
                        }
                        Err(e) => {
                            eprintln!(
                                "coordinator: engine construction failed on shard {shard}: {e}"
                            );
                            // Construction failures are supervised like
                            // mid-serve deaths (empty salvage), and the
                            // mailbox keeps answering — a silently
                            // dropped message is a client hung forever.
                            let _ = events.send(WorkerEvent::Down {
                                shard,
                                generation,
                                salvage: Vec::new(),
                                trace: Vec::new(),
                                latency: LatencyReport::default(),
                                traffic: TrafficSnapshot::default(),
                            });
                            tombstone_loop(shard, generation, rx, &events);
                        }
                    }
                });
                Worker { tx, handle, generation }
            });
            workers.push(spawn(0));
            spawners.push(spawn);
        }
        Server {
            workers,
            spawners,
            restarts: vec![0; n_shards],
            retired: Vec::new(),
            shards: ShardMap::new(n_shards),
            router: RouterPolicy::default(),
            mode: MigrationMode::Move,
            done_rx,
            event_rx,
            sessions: BTreeMap::new(),
            max_restarts: 2,
            max_replays: 3,
            stats: ResilienceStats::default(),
            router_trace: Vec::new(),
            dead_trace: Vec::new(),
            dead_latency: LatencyReport::default(),
            dead_traffic: TrafficSnapshot::default(),
            frontend_traffic: TrafficSnapshot::default(),
        }
    }

    /// Record a router-scoped lifecycle event for `seq` (tick 0: the
    /// router is clockless; worker records carry the real tick).
    fn router_record(&mut self, seq: u64, shard: usize, event: TraceEvent) {
        self.router_trace.push(TraceRecord { seq, tick: 0, shard: shard as u32, event });
    }

    /// Replace the router's migration heuristics.
    pub fn set_router_policy(&mut self, policy: RouterPolicy) {
        self.router = policy.normalized();
    }

    /// How migrations are realized ([`MigrationMode::Move`] by default;
    /// [`MigrationMode::Reprefill`] is the counter-gate baseline).
    pub fn set_migration_mode(&mut self, mode: MigrationMode) {
        self.mode = mode;
    }

    /// The router's request → shard placement map (tests/diagnostics).
    pub fn shard_map(&self) -> &ShardMap {
        &self.shards
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Absorb the workers' completion notifications into the tracked
    /// placement map, then handle any pending supervision events.
    fn drain_completions(&mut self) {
        while let Ok(seq) = self.done_rx.try_recv() {
            self.shards.complete(seq);
        }
        self.supervise();
    }

    /// Respawn budget per shard (default 2; 0 disables respawn — a dead
    /// shard stays retired).
    pub fn set_max_restarts(&mut self, n: u32) {
        self.max_restarts = n;
    }

    /// Per-request fault-replay budget (default 3): a flight the
    /// supervisor has already re-routed this many times fails
    /// terminally — an explicit error [`Response`] at the client —
    /// instead of looping through re-prefill forever.
    pub fn set_max_replays(&mut self, n: u32) {
        self.max_replays = n;
    }

    /// Supervision counters accumulated so far.
    pub fn resilience(&self) -> ResilienceStats {
        self.stats
    }

    /// Drain and handle pending supervision events: retire dead shards
    /// (dropping their session pins and reconciling tracked load),
    /// respawn within the restart budget, re-route salvaged flights to
    /// healthy workers, and resubmit orphaned requests. Returns the
    /// number of events handled.
    ///
    /// Every routing entry point calls this, so a server under steady
    /// traffic supervises itself. A caller that stops submitting and
    /// blocks on response receivers must pump it while waiting (e.g.
    /// `recv_timeout` + `supervise()` in a loop) — the supervisor lives
    /// on the router thread by design, exactly like `rebalance`.
    pub fn supervise(&mut self) -> usize {
        let mut handled = 0;
        while let Ok(ev) = self.event_rx.try_recv() {
            handled += 1;
            match ev {
                WorkerEvent::Down { shard, generation, salvage, trace, latency, traffic } => {
                    self.dead_trace.extend(trace);
                    self.dead_latency.merge(&latency);
                    self.dead_traffic.accumulate(&traffic);
                    self.handle_down(shard, generation, salvage)
                }
                WorkerEvent::Orphan { req, session, sink } => {
                    self.reroute_orphan(req, session, sink)
                }
            }
        }
        handled
    }

    fn handle_down(&mut self, shard: usize, generation: u64, salvage: Vec<SalvageEntry>) {
        // Generation guard: a tombstone can bounce late messages (as
        // further `Down` events carrying their salvage) after the shard
        // already respawned — those must not retire the healthy
        // replacement. A cap-exhausted shard keeps its final generation
        // in `workers`, so the dead-shard check is what de-duplicates
        // echoes of an un-respawned death. Their salvage is still
        // re-routed below.
        let current = self.workers.get(shard).map(|w| w.generation);
        if current == Some(generation) && !self.shards.is_dead(shard) {
            self.stats.workers_down += 1;
            // Retire first: drops every tracked placement on the shard
            // (their completions will never arrive) and takes it out of
            // routing. The flights the worker could save arrive in
            // `salvage`; queued-but-unstarted submits bounce back as
            // `Orphan` events from the tombstone.
            let _orphaned = self.shards.retire(shard);
            // Session pins on the dead shard drop so follow-ups miss
            // cleanly (place anew) instead of chasing a lost cache.
            self.sessions.retain(|_, s| *s != shard);
            // Respawn before re-routing, so a single-worker server can
            // re-route its salvage onto its own replacement.
            if self.restarts[shard] < self.max_restarts {
                self.restarts[shard] += 1;
                self.stats.worker_restarts += 1;
                // Bounded backoff: 2ms, 4ms, … capped — enough to not
                // hot-spin on a construction that keeps failing, short
                // enough for tests.
                std::thread::sleep(std::time::Duration::from_millis(
                    1u64 << self.restarts[shard].min(6),
                ));
                let replacement = (self.spawners[shard])(generation + 1);
                let old = std::mem::replace(&mut self.workers[shard], replacement);
                self.retired.push(old.handle);
                self.shards.revive(shard);
            }
        }
        for (packet, sink) in salvage {
            self.reroute_salvage(packet, sink);
        }
    }

    /// Re-route one salvaged flight: state-carrying packets `attach` on
    /// the target (falling back to re-prefill exactly like the
    /// malformed-packet path); token-only packets go straight to
    /// re-prefill. Budget-exhausted or unroutable flights fail
    /// terminally — their sink always gets exactly one message.
    fn reroute_salvage(&mut self, mut packet: Box<MigrationPacket>, sink: Sender<Response>) {
        let seq = packet.seq();
        if packet.flight.replays >= self.max_replays {
            self.fail_request(
                seq,
                sink,
                format!(
                    "retry budget exhausted after {} fault re-routes",
                    packet.flight.replays
                ),
            );
            return;
        }
        if !self.shards.has_live() {
            self.fail_request(seq, sink, "no healthy worker available");
            return;
        }
        packet.flight.replays += 1;
        let carried = packet.state_bytes() > 0;
        let mode = if carried {
            MigrationMode::Move
        } else {
            // Token-only packets would be rejected by attach's shape
            // validation anyway; route them straight to re-prefill.
            MigrationMode::Reprefill
        };
        let shard = self.shards.place(seq);
        match self.workers[shard].tx.send(Msg::Attach(packet, sink, mode)) {
            Ok(()) => {
                if carried {
                    self.stats.requests_salvaged += 1;
                } else {
                    self.stats.requests_reprefilled_on_fault += 1;
                }
            }
            Err(std::sync::mpsc::SendError(msg)) => {
                if let Msg::Attach(_, sink, _) = msg {
                    self.fail_request(seq, sink, "worker lost while re-routing");
                }
            }
        }
    }

    /// Re-route a submit that bounced off a dead worker's mailbox.
    fn reroute_orphan(&mut self, req: Request, session: Option<u64>, sink: Sender<Response>) {
        if !self.shards.has_live() {
            self.fail_request(req.id, sink, "no healthy worker available");
            return;
        }
        let shard = self.shards.place(req.id);
        self.router_record(req.id, shard, TraceEvent::Routed { shard: shard as u32 });
        if let Some(sid) = session {
            self.sessions.insert(sid, shard);
        }
        let msg = match session {
            Some(sid) => Msg::SubmitSession(req, sid, sink),
            None => Msg::Submit(req, sink),
        };
        if let Err(std::sync::mpsc::SendError(msg)) = self.workers[shard].tx.send(msg) {
            self.fail_submit_msg(msg, "worker lost while re-routing");
        }
    }

    /// Terminal failure: exactly one error message to the sink, router
    /// bookkeeping released.
    fn fail_request(&mut self, seq: u64, sink: Sender<Response>, reason: impl Into<String>) {
        self.stats.requests_failed += 1;
        let shard = self.shards.shard_of(seq).unwrap_or(0);
        self.shards.complete(seq);
        self.router_record(seq, shard, TraceEvent::Failed);
        let _ = sink.send(Response::failure(seq, reason));
    }

    /// Unwrap a failed submit-message send and fail it terminally.
    fn fail_submit_msg(&mut self, msg: Msg, reason: &str) {
        if let Msg::Submit(req, sink) | Msg::SubmitSession(req, _, sink) = msg {
            self.fail_request(req.id, sink, reason);
        }
    }

    /// Route a request to the least-loaded worker (slot-aware: tracked
    /// in-flight count per shard); returns the response channel.
    pub fn submit(&mut self, req: Request) -> Receiver<Response> {
        self.drain_completions();
        if let Some(rx) = self.reject_duplicate(&req) {
            return rx;
        }
        let shard = self.shards.place(req.id);
        self.router_record(req.id, shard, TraceEvent::Routed { shard: shard as u32 });
        self.send_submit(req, shard)
    }

    /// Route a request to an explicit worker (benchmarks use this to
    /// create hot-shard skew; production callers want [`Server::submit`]).
    ///
    /// The pin is validated against the dead-shard mask, exactly like
    /// a stale session pin in [`Server::submit_session`]: a request
    /// pinned onto a retired shard would bounce off its tombstone and
    /// burn an orphan round-trip through the supervisor, so it is
    /// re-routed to a live shard up front instead.
    pub fn submit_to(&mut self, req: Request, shard: usize) -> Receiver<Response> {
        self.drain_completions();
        if let Some(rx) = self.reject_duplicate(&req) {
            return rx;
        }
        let shard = shard.min(self.workers.len().saturating_sub(1));
        let shard = if self.shards.is_dead(shard) && self.shards.has_live() {
            self.shards.place(req.id)
        } else {
            self.shards.assign(req.id, shard);
            shard
        };
        self.router_record(req.id, shard, TraceEvent::Routed { shard: shard as u32 });
        self.send_submit(req, shard)
    }

    /// Terminal admission rejection from the serving front-end: the
    /// request never reaches a worker. Records a `Submit` + `Failed`
    /// span at the router (tick 0 — the router is clockless) so the
    /// lifecycle trace still accounts for the request with exactly one
    /// terminal event, bumps the shed counters folded into
    /// [`Server::traffic`], and returns the request's exactly-one
    /// terminal error [`Response`] for the caller to deliver.
    ///
    /// `class` is the request's priority-class index
    /// (`< `[`super::metrics::PRIORITY_CLASSES`]; out-of-range indexes
    /// still count toward the total, just not a per-class bucket).
    pub fn shed_request(&mut self, id: u64, class: usize, reason: impl Into<String>) -> Response {
        self.frontend_traffic.requests_shed += 1;
        if let Some(c) = self.frontend_traffic.shed_by_class.get_mut(class) {
            *c += 1;
        }
        self.router_record(id, 0, TraceEvent::Submit);
        self.router_record(id, 0, TraceEvent::Failed);
        Response::failure(id, reason)
    }

    /// Record a front-end admission in the per-class counters (the
    /// admitted request itself flows through the normal
    /// [`Server::submit`] path).
    pub fn record_admitted(&mut self, class: usize) {
        if let Some(c) = self.frontend_traffic.admitted_by_class.get_mut(class) {
            *c += 1;
        }
    }

    /// Submit a request under a session: follow-up turns route to the
    /// shard that owns the session's snapshot cache entry, so a prompt
    /// extending the previous turn attaches the cached state and
    /// prefills only the new tokens. The first submit under a session
    /// places it least-loaded and pins the session there.
    pub fn submit_session(&mut self, req: Request, session: u64) -> Receiver<Response> {
        self.drain_completions();
        if let Some(rx) = self.reject_duplicate(&req) {
            return rx;
        }
        // A pin onto a retired shard is stale (supervision drops pins
        // at retire time, but a pin can also go stale between a death
        // and its Down event): place anew rather than chase it.
        let shard = match self.sessions.get(&session) {
            Some(&s) if !self.shards.is_dead(s) => {
                self.shards.assign(req.id, s);
                s
            }
            _ => {
                let s = self.shards.place(req.id);
                self.sessions.insert(session, s);
                s
            }
        };
        self.router_record(req.id, shard, TraceEvent::Routed { shard: shard as u32 });
        let (tx, rx) = channel();
        match self.workers.get(shard) {
            Some(w) => {
                if let Err(std::sync::mpsc::SendError(msg)) =
                    w.tx.send(Msg::SubmitSession(req, session, tx))
                {
                    self.fail_submit_msg(msg, "worker channel closed");
                }
            }
            None => self.fail_request(req.id, tx, "no such worker"),
        }
        rx
    }

    /// Copy-on-write session fork: register `child` as a session
    /// sharing `parent`'s cached state (zero bytes copied — each
    /// child's first submit pays the one counted attach). Returns
    /// `false` when the parent has no snapshot.
    pub fn fork_session(&mut self, parent: u64, child: u64) -> bool {
        let Some(&shard) = self.sessions.get(&parent) else {
            return false;
        };
        let Some(w) = self.workers.get(shard) else {
            return false;
        };
        let (tx, rx) = channel();
        if w.tx.send(Msg::Fork(parent, child, tx)).is_err() {
            return false;
        }
        let ok = rx.recv().unwrap_or(false);
        if ok {
            // The child shares the parent's cache, so it pins to the
            // same shard.
            self.sessions.insert(child, shard);
        }
        ok
    }

    /// Replace every worker's snapshot-cache LRU byte budget (`0`
    /// disables session caching).
    pub fn set_snapshot_budget(&self, bytes: u64) {
        for w in &self.workers {
            let _ = w.tx.send(Msg::SnapshotBudget(bytes));
        }
    }

    /// Router-level duplicate guard: an id the placement map still
    /// tracks is in flight on some worker, and submitting it again
    /// would (before the scheduler's own guard existed) silently
    /// re-zero its resident state row mid-generation. Returns a dead
    /// receiver — the caller's `recv()` errors instead of hanging —
    /// and leaves the original request untouched.
    fn reject_duplicate(&self, req: &Request) -> Option<Receiver<Response>> {
        if self.shards.shard_of(req.id).is_some() {
            eprintln!(
                "coordinator: rejected request {}: id already in flight",
                req.id
            );
            let (_tx, rx) = channel();
            return Some(rx);
        }
        None
    }

    /// Send a submit to `shard`'s mailbox. If the worker is gone (no
    /// such shard, or its channel closed before the tombstone took
    /// over), the request fails terminally — the returned receiver
    /// yields an error [`Response`], never a silent disconnect.
    fn send_submit(&mut self, req: Request, shard: usize) -> Receiver<Response> {
        let (tx, rx) = channel();
        match self.workers.get(shard) {
            Some(w) => {
                if let Err(std::sync::mpsc::SendError(msg)) = w.tx.send(Msg::Submit(req, tx)) {
                    self.fail_submit_msg(msg, "worker channel closed");
                }
            }
            None => self.fail_request(req.id, tx, "no such worker"),
        }
        rx
    }

    /// Live load snapshot of every worker (queried over the channels).
    pub fn loads(&self) -> Vec<WorkerLoad> {
        self.workers
            .iter()
            .filter_map(|w| {
                let (tx, rx) = channel();
                w.tx.send(Msg::Load(tx)).ok()?;
                rx.recv().ok()
            })
            .collect()
    }

    /// Push the global resident-state gauge to every worker: each
    /// scheduler learns the resident bytes on the *other* shards, so
    /// the planner's per-tick `WorkloadFeatures` carry the server-wide
    /// gauge instead of one shard's slice.
    pub fn sync_global_resident(&self) {
        let loads = self.loads();
        let total: u64 = loads.iter().map(|l| l.resident_bytes).sum();
        for l in &loads {
            if let Some(w) = self.workers.get(l.shard) {
                let _ = w.tx.send(Msg::RemoteResident(total - l.resident_bytes));
            }
        }
    }

    /// One rebalance pass: plan migrations off the hottest shards under
    /// the [`RouterPolicy`] hysteresis, execute each over the worker
    /// channels, and re-sync the global resident gauge. A planned move
    /// can miss (the request completed, or holds no state yet); misses
    /// are deferred so the next rounds don't retry them immediately.
    pub fn rebalance(&mut self) -> MigrationOutcome {
        self.drain_completions();
        let planned = self.shards.plan_rebalance(&self.router);
        let mut out = MigrationOutcome { planned: planned.len(), migrated: 0 };
        for m in &planned {
            if self.migrate_between(m.seq, m.from, m.to) {
                self.shards.apply(m, &self.router);
                out.migrated += 1;
            } else {
                self.shards.defer(m.seq, &self.router);
            }
        }
        self.sync_global_resident();
        out
    }

    /// Force one migration (tests / conformance): move `seq` to worker
    /// `to` regardless of load. Returns false when the request is not
    /// currently migratable (unknown, completed, pre-state, already
    /// there).
    pub fn force_migrate(&mut self, seq: u64, to: usize) -> bool {
        self.drain_completions();
        let Some(from) = self.shards.shard_of(seq) else { return false };
        // A retired target must be refused up front: its tombstone's
        // channel is still open, so the Attach send would *succeed*,
        // this method would report true, and `ShardMap::apply` would
        // record the request (and its tracked load) on a dead shard —
        // until the tombstone's Down echo unwinds it a supervision
        // round later.
        if from == to || to >= self.workers.len() || self.shards.is_dead(to) {
            return false;
        }
        if self.migrate_between(seq, from, to) {
            self.shards.apply(&Migration { seq, from, to }, &self.router);
            true
        } else {
            false
        }
    }

    /// Execute one migration over the channels: detach (packet + sink)
    /// from the source worker, attach on the target. The state is in
    /// exactly one arena at every observable instant — the source
    /// releases it before replying, and the target's attach message is
    /// ordered before any later query on its channel. If the target
    /// worker is gone (its mailbox dropped), the packet bounces back to
    /// the source as a state move, so a failed migration never destroys
    /// an in-flight request.
    fn migrate_between(&self, seq: u64, from: usize, to: usize) -> bool {
        let (tx, rx) = channel();
        if self.workers[from].tx.send(Msg::Detach(seq, tx)).is_err() {
            return false;
        }
        let Ok(Some((packet, sink))) = rx.recv() else { return false };
        match self.workers[to].tx.send(Msg::Attach(packet, sink, self.mode)) {
            Ok(()) => true,
            Err(std::sync::mpsc::SendError(msg)) => {
                if let Msg::Attach(packet, sink, _) = msg {
                    // Re-attach where it came from — always as a state
                    // move: the packet holds the authoritative state.
                    let _ = self.workers[from]
                        .tx
                        .send(Msg::Attach(packet, sink, MigrationMode::Move));
                }
                false
            }
        }
    }

    /// Each worker engine's capability report (what the schedulers
    /// negotiated from at construction) — `serve_mamba` prints the
    /// first one as the startup `engine caps:` line.
    pub fn caps(&self) -> Vec<EngineCaps> {
        self.workers
            .iter()
            .filter_map(|w| {
                let (tx, rx) = channel();
                w.tx.send(Msg::Caps(tx)).ok()?;
                rx.recv().ok()
            })
            .collect()
    }

    /// Collect metrics reports from all workers.
    pub fn reports(&self) -> Vec<String> {
        self.workers
            .iter()
            .filter_map(|w| {
                let (tx, rx) = channel();
                w.tx.send(Msg::Report(tx)).ok()?;
                rx.recv().ok()
            })
            .collect()
    }

    /// Aggregate the state-traffic, migration and plan counters across
    /// all workers. Counters sum. The `state_bytes_resident` *gauge*
    /// also sums — and the sum is the one global gauge, not a double
    /// count: per-shard residency is disjoint, and a migrated row is
    /// resident on exactly one shard at any instant (the source worker
    /// releases it before the detach reply, the target installs it on
    /// attach, and each worker's gauge updates immediately — between
    /// ticks — on both sides of the move). Migrations themselves are
    /// counted once each, on the attaching worker.
    /// Counters from workers that died mid-serve are preserved: each
    /// death ships its final snapshot (gauges zeroed) in its `Down`
    /// event, and the sum here includes them — so the server-wide
    /// counters never go backwards across a fault, and the lifecycle
    /// trace reconciles against them exactly ([`crate::obs::reconcile`]).
    pub fn traffic(&self) -> TrafficSnapshot {
        let mut total = self.dead_traffic;
        total.accumulate(&self.frontend_traffic);
        for w in &self.workers {
            let (tx, rx) = channel();
            if w.tx.send(Msg::Traffic(tx)).is_err() {
                continue;
            }
            if let Ok(t) = rx.recv() {
                total.accumulate(&t);
            }
        }
        total
    }

    /// Drain the full request-lifecycle trace: router-scoped records
    /// (`Routed` / `Failed`), every live worker's ring (over the same
    /// channels every other query uses), and records recovered from
    /// dead workers' `Down` events. Each call returns a fresh window —
    /// records are drained exactly once, so consecutive windows
    /// reconcile against counter *deltas* (and one drain at end of run
    /// reconciles against the totals). Per-seq record order is
    /// router → per-worker in drain order; tick stamps are per-worker
    /// clocks ([`crate::obs::assemble_spans`] stitches by sequence, not
    /// by comparing ticks across shards).
    pub fn trace(&mut self) -> Vec<TraceRecord> {
        self.supervise(); // pick up pending Down events' traces first
        let mut all = std::mem::take(&mut self.router_trace);
        for w in &self.workers {
            let (tx, rx) = channel();
            if w.tx.send(Msg::Trace(tx)).is_err() {
                continue;
            }
            if let Ok(mut t) = rx.recv() {
                all.append(&mut t);
            }
        }
        all.append(&mut self.dead_trace);
        all
    }

    /// Server-wide latency histograms: every live worker's
    /// [`LatencyReport`] plus those recovered from dead workers, pooled
    /// via [`crate::obs::Histogram::merge`] — the percentiles are
    /// exactly those of the pooled samples (what the old
    /// last-writer-wins report lines could never give), in both wall
    /// and tick units.
    pub fn latency(&mut self) -> LatencyReport {
        self.supervise();
        let mut total = self.dead_latency;
        for w in &self.workers {
            let (tx, rx) = channel();
            if w.tx.send(Msg::Latency(tx)).is_err() {
                continue;
            }
            if let Ok(l) = rx.recv() {
                total.merge(&l);
            }
        }
        total
    }

    /// Graceful shutdown: drains in-flight work first. Pending
    /// supervision events are handled before the workers stop, so
    /// salvaged flights still re-route rather than vanish.
    pub fn shutdown(mut self) {
        self.supervise();
        for w in &self.workers {
            let _ = w.tx.send(Msg::Shutdown);
        }
        for w in self.workers {
            let _ = w.handle.join();
        }
        // Tombstones of replaced workers exit when their mailbox
        // disconnects (their Sender was dropped at respawn).
        for h in self.retired {
            let _ = h.join();
        }
    }
}

/// Hand a submit (plain or session-tagged) to the worker's scheduler,
/// releasing the sink and notifying the router if it is rejected.
fn accept_submit<E: Executor>(
    sched: &mut Scheduler<E>,
    sinks: &mut std::collections::BTreeMap<u64, Sender<Response>>,
    done: &Sender<u64>,
    req: Request,
    session: Option<u64>,
    sink: Sender<Response>,
) {
    let id = req.id;
    sinks.insert(id, sink);
    if let Err(e) = sched.submit_session(req, session) {
        eprintln!("coordinator: rejected request: {e}");
        // The request will never complete: send its one terminal
        // message (an explicit error — the client's recv() returns it
        // instead of hanging or surprising with a disconnect) and tell
        // the router so its tracked placement doesn't leak a phantom
        // load entry.
        if let Some(sink) = sinks.remove(&id) {
            let _ = sink.send(Response::failure(id, format!("rejected: {e}")));
        }
        let _ = done.send(id);
    }
}

/// Apply one mailbox message to the worker's scheduler/sink state.
/// Shared by the non-blocking drain and the idle blocking receive.
fn handle_msg<E: Executor>(
    msg: Msg,
    sched: &mut Scheduler<E>,
    sinks: &mut std::collections::BTreeMap<u64, Sender<Response>>,
    shard: usize,
    done: &Sender<u64>,
    shutting_down: &mut bool,
) {
    match msg {
        Msg::Submit(req, sink) => {
            accept_submit(sched, sinks, done, req, None, sink);
        }
        Msg::SubmitSession(req, session, sink) => {
            accept_submit(sched, sinks, done, req, Some(session), sink);
        }
        Msg::Fork(parent, child, tx) => {
            let _ = tx.send(sched.fork_session(parent, child));
        }
        Msg::SnapshotBudget(bytes) => sched.set_snapshot_budget(bytes),
        Msg::Report(tx) => {
            let _ = tx.send(sched.metrics().report());
        }
        Msg::Traffic(tx) => {
            let _ = tx.send(sched.metrics().traffic_snapshot());
        }
        Msg::Trace(tx) => {
            let _ = tx.send(sched.take_trace());
        }
        Msg::Latency(tx) => {
            let _ = tx.send(sched.latency_report());
        }
        Msg::Caps(tx) => {
            let _ = tx.send(sched.caps());
        }
        Msg::Load(tx) => {
            let _ = tx.send(WorkerLoad {
                shard,
                running: sched.running(),
                waiting: sched.waiting(),
                resident_bytes: sched.state_arena().resident_bytes(),
            });
        }
        Msg::Detach(seq, tx) => {
            // The sink must travel with the flight; refuse the detach
            // if we don't hold one (nothing to route the response to).
            let reply = if sinks.contains_key(&seq) {
                sched.detach(seq).map(|p| {
                    let sink = sinks.remove(&seq).expect("checked above");
                    (Box::new(p), sink)
                })
            } else {
                None
            };
            let _ = tx.send(reply);
        }
        Msg::Attach(packet, sink, mode) => {
            sinks.insert(packet.seq(), sink);
            match mode {
                MigrationMode::Move => {
                    // A malformed packet (corrupt cursor, wrong payload
                    // shape, …) is rejected by the scheduler *before*
                    // touching any state — instead of unwinding this
                    // worker we rebuild the request from its tokens,
                    // which trusts nothing but the flight bookkeeping.
                    if let Err(p) = sched.attach(*packet) {
                        eprintln!(
                            "coordinator: rejected malformed migration packet for \
                             seq {}; rebuilding by re-prefill",
                            p.seq()
                        );
                        sched.attach_reprefill(p);
                    }
                }
                MigrationMode::Reprefill => sched.attach_reprefill(*packet),
            }
        }
        Msg::RemoteResident(bytes) => sched.set_remote_resident_bytes(bytes),
        Msg::Shutdown => *shutting_down = true,
    }
}

fn worker_loop<E: Executor>(
    engine: E,
    policy: BatchPolicy,
    spec: PlanSpec,
    shard: usize,
    generation: u64,
    rx: Receiver<Msg>,
    done: Sender<u64>,
    events: Sender<WorkerEvent>,
) {
    // The state path is negotiated from the engine's caps (resident for
    // in-place-capable engines, packed reference otherwise).
    let mut sched = Scheduler::with_planner_auto(engine, policy, Planner::new(spec));
    sched.set_shard(shard);
    let mut sinks: std::collections::BTreeMap<u64, Sender<Response>> =
        std::collections::BTreeMap::new();
    let mut shutting_down = false;
    loop {
        // Drain the mailbox without blocking while work is in flight.
        while !shutting_down {
            match rx.try_recv() {
                Ok(msg) => {
                    handle_msg(msg, &mut sched, &mut sinks, shard, &done, &mut shutting_down)
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => shutting_down = true,
            }
        }
        if shutting_down && sched.pending() == 0 {
            return;
        }

        match sched.tick() {
            Ok((completed, progressed)) => {
                for resp in completed {
                    let _ = done.send(resp.id);
                    if let Some(sink) = sinks.remove(&resp.id) {
                        let _ = sink.send(resp);
                    }
                }
                if !progressed {
                    if shutting_down && sched.pending() == 0 {
                        return;
                    }
                    // Idle: block briefly for new work.
                    if let Ok(msg) = rx.recv_timeout(std::time::Duration::from_millis(1)) {
                        handle_msg(msg, &mut sched, &mut sinks, shard, &done, &mut shutting_down);
                    }
                }
            }
            Err(e) => {
                eprintln!("coordinator: engine error on shard {shard}: {e}");
                // Salvage instead of fail-stop: the poisoned scheduler
                // exports every in-flight sequence (untouched rows with
                // their state, suspect rows as token-only re-prefills)
                // and the supervisor re-routes them. Sinks travel with
                // their flights; any sink left without a flight gets
                // its terminal error here — a dead worker never
                // silently drops a client.
                // Trace and latency must come off the scheduler
                // *before* `salvage()` consumes it; the fault tick is
                // already in the ring (the failing tick pushed it).
                let mut trace = sched.take_trace();
                let fault_tick = sched.tick_count();
                let latency = sched.latency_report();
                let traffic = {
                    // Gauges measure state that dies with the worker
                    // (rows are salvaged off or lost; the snapshot
                    // cache is gone) — zero them so the server-wide
                    // sums stay honest. Monotone counters survive.
                    let mut t = sched.metrics().traffic_snapshot();
                    t.state_bytes_resident = 0;
                    t.snapshot_bytes_cached = 0;
                    t
                };
                let mut salvage: Vec<SalvageEntry> = Vec::new();
                for packet in sched.salvage() {
                    let seq = packet.seq();
                    trace.push(TraceRecord {
                        seq,
                        tick: fault_tick,
                        shard: shard as u32,
                        event: TraceEvent::Salvaged {
                            state_carrying: packet.state_bytes() > 0,
                        },
                    });
                    match sinks.remove(&seq) {
                        Some(sink) => salvage.push((Box::new(packet), sink)),
                        // No sink, no observer: nothing to route the
                        // response to (detach in flight) — drop it and
                        // release the router's tracking.
                        None => {
                            let _ = done.send(seq);
                        }
                    }
                }
                for (id, sink) in std::mem::take(&mut sinks) {
                    let _ = sink.send(Response::failure(id, "worker failed with no salvageable flight"));
                    let _ = done.send(id);
                }
                let _ = events.send(WorkerEvent::Down {
                    shard,
                    generation,
                    salvage,
                    trace,
                    latency,
                    traffic,
                });
                tombstone_loop(shard, generation, rx, &events);
                return;
            }
        }
    }
}

/// Mailbox service for a dead worker. The scheduler is gone, but the
/// channel must keep answering until the supervisor replaces the worker
/// (dropping this receiver's sender) or shuts down — any message racing
/// the death would otherwise be silently dropped, and a dropped submit
/// is a client hung on `recv()` forever. Submits bounce back to the
/// supervisor as `Orphan` events for re-routing; attaches re-enter the
/// salvage path (a stale-generation `Down` whose salvage the supervisor
/// re-routes without retiring anything); detaches report "not here";
/// queries get their reply channel dropped, which the router already
/// treats as "worker gone".
fn tombstone_loop(shard: usize, generation: u64, rx: Receiver<Msg>, events: &Sender<WorkerEvent>) {
    while let Ok(msg) = rx.recv() {
        let forwarded = match msg {
            Msg::Submit(req, sink) => events.send(WorkerEvent::Orphan { req, session: None, sink }),
            Msg::SubmitSession(req, session, sink) => {
                events.send(WorkerEvent::Orphan { req, session: Some(session), sink })
            }
            Msg::Attach(packet, sink, _) => events.send(WorkerEvent::Down {
                shard,
                generation,
                salvage: vec![(packet, sink)],
                trace: Vec::new(),
                latency: LatencyReport::default(),
                traffic: TrafficSnapshot::default(),
            }),
            Msg::Fork(_, _, tx) => {
                let _ = tx.send(false);
                Ok(())
            }
            Msg::Detach(_, tx) => {
                let _ = tx.send(None);
                Ok(())
            }
            // Dropping the reply sender makes the router's recv() fail,
            // which every query path already skips over.
            Msg::Report(_) | Msg::Traffic(_) | Msg::Trace(_) | Msg::Latency(_) | Msg::Caps(_)
            | Msg::Load(_) => Ok(()),
            Msg::SnapshotBudget(_) | Msg::RemoteResident(_) => Ok(()),
            Msg::Shutdown => return,
        };
        if forwarded.is_err() {
            // Supervisor gone: nobody left to re-route to.
            return;
        }
    }
}

/// Convenience: serve a fixed batch of requests to completion on one
/// executor and return (responses, metrics report).
pub fn serve_all<E, F>(
    factory: F,
    policy: BatchPolicy,
    reqs: Vec<Request>,
) -> Result<(Vec<Response>, String)>
where
    E: Executor,
    F: FnMut() -> anyhow::Result<E> + Send + 'static,
{
    let mut server = Server::start(vec![factory], policy);
    let sinks: Vec<Receiver<Response>> =
        reqs.into_iter().map(|r| server.submit(r)).collect();
    let mut responses = Vec::new();
    for rx in sinks {
        responses.push(rx.recv()?);
    }
    let report = server.reports().join("\n");
    server.shutdown();
    Ok((responses, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::WorkloadGen;
    use crate::runtime::mock::MockEngine;

    #[test]
    fn serve_all_round_trips() {
        let probe = MockEngine::new();
        let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
        let mut gen = WorkloadGen::new(9, vocab, plen, 2, 5);
        let reqs: Vec<Request> = (0..10).map(|_| gen.next_request()).collect();
        let want: Vec<(u64, usize)> =
            reqs.iter().map(|r| (r.id, r.max_new_tokens)).collect();
        let (mut resps, report) =
            serve_all(|| Ok(MockEngine::new()), BatchPolicy::default(), reqs).unwrap();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), want.len());
        for (resp, (id, n)) in resps.iter().zip(&want) {
            assert_eq!(resp.id, *id);
            assert_eq!(resp.tokens.len(), *n);
        }
        assert!(report.contains("requests=10"), "{report}");
    }

    #[test]
    fn multi_worker_routing_balances() {
        let probe = MockEngine::new();
        let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
        let factories: Vec<fn() -> anyhow::Result<MockEngine>> =
            vec![|| Ok(MockEngine::new()), || Ok(MockEngine::new())];
        let mut server = Server::start(factories, BatchPolicy::default());
        let mut gen = WorkloadGen::new(11, vocab, plen, 2, 2);
        let rxs: Vec<_> = (0..8).map(|_| server.submit(gen.next_request())).collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.tokens.len(), 2);
        }
        let reports = server.reports();
        assert_eq!(reports.len(), 2);
        // Both workers saw traffic.
        for r in &reports {
            assert!(!r.contains("requests=0"), "{r}");
        }
        server.shutdown();
    }

    #[test]
    fn duplicate_submit_gets_dead_receiver_and_original_survives() {
        let mut server =
            Server::start(vec![|| Ok(MockEngine::new())], BatchPolicy::default());
        let rx1 = server.submit(Request { id: 1, prompt: vec![2, 3, 4], max_new_tokens: 512 });
        // Same id while the original is in flight: the router hands
        // back a dead receiver instead of letting the worker re-zero
        // the original's resident state row.
        let rx_dup = server.submit(Request { id: 1, prompt: vec![9, 9], max_new_tokens: 4 });
        assert!(rx_dup.recv().is_err(), "duplicate id must be rejected");
        let resp = rx1.recv().unwrap();
        assert_eq!(resp.tokens.len(), 512, "original request unharmed");
        server.shutdown();
    }

    #[test]
    fn shutdown_with_no_work_is_clean() {
        let server = Server::start(vec![|| Ok(MockEngine::new())], BatchPolicy::default());
        server.shutdown();
    }

    #[test]
    fn server_reports_worker_caps() {
        let server = Server::start(
            vec![|| Ok(MockEngine::new()), || Ok(MockEngine::new())],
            BatchPolicy::default(),
        );
        let caps = server.caps();
        assert_eq!(caps.len(), 2);
        for c in &caps {
            assert!(c.varlen_kernel, "mock workers advertise the fused kernel");
            assert!(!c.summary().is_empty());
        }
        server.shutdown();
    }

    #[test]
    fn traffic_aggregates_across_workers_and_is_zero_on_mock() {
        // The mock engine is fused, so the resident hot path moves no
        // state bytes no matter how many workers serve the load.
        let probe = MockEngine::new();
        let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
        let factories: Vec<fn() -> anyhow::Result<MockEngine>> =
            vec![|| Ok(MockEngine::new()), || Ok(MockEngine::new())];
        let mut server = Server::start(factories, BatchPolicy::default());
        let mut gen = WorkloadGen::new(5, vocab, plen, 2, 3);
        let rxs: Vec<_> = (0..6).map(|_| server.submit(gen.next_request())).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let t = server.traffic();
        assert_eq!(t.bytes_gathered, 0);
        assert_eq!(t.bytes_scattered, 0);
        assert_eq!(t.padded_rows, 0);
        assert_eq!(t.state_bytes_resident, 0, "all slots released after drain");
        // No rebalance ran: nothing migrated.
        assert_eq!(t.migrations, 0);
        assert_eq!(t.bytes_migrated, 0);
        // Plan counters aggregate across both workers: every tick ran
        // under some plan, and the mock modeled its cost.
        assert!(t.ticks_per_plan.iter().sum::<u64>() > 0);
        assert!(t.modeled_cycles > 0);
        assert!(t.predicted_cycles > 0);
        server.shutdown();
    }

    #[test]
    fn static_plan_spec_serves_identically() {
        use crate::fusion::FusionVariant;
        use crate::planner::{PlanChoice, PlanSpec};
        let probe = MockEngine::new();
        let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
        let serve = |spec: PlanSpec| {
            let mut server = Server::start_planned(
                vec![|| Ok(MockEngine::new())],
                BatchPolicy::default(),
                spec,
            );
            let mut gen = WorkloadGen::new(8, vocab, plen, 2, 4);
            let rxs: Vec<_> = (0..6).map(|_| server.submit(gen.next_request())).collect();
            let mut toks: Vec<Vec<i32>> = rxs.into_iter().map(|rx| rx.recv().unwrap().tokens).collect();
            toks.sort();
            let t = server.traffic();
            server.shutdown();
            (toks, t)
        };
        let (adaptive_tokens, _) = serve(PlanSpec::Adaptive);
        let (static_tokens, t) =
            serve(PlanSpec::Static(PlanChoice::Variant(FusionVariant::RIOnly)));
        assert_eq!(adaptive_tokens, static_tokens);
        // A static spec runs every tick under the one plan.
        let ri = PlanChoice::Variant(FusionVariant::RIOnly).index();
        assert_eq!(t.ticks_per_plan.iter().sum::<u64>(), t.ticks_per_plan[ri]);
        assert_eq!(t.plan_switches, 0);
    }

    #[test]
    fn completions_release_tracked_load() {
        let probe = MockEngine::new();
        let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
        let mut server = Server::start(
            vec![|| Ok(MockEngine::new()), || Ok(MockEngine::new())],
            BatchPolicy::default(),
        );
        let mut gen = WorkloadGen::new(3, vocab, plen, 2, 3);
        let rxs: Vec<_> = (0..6).map(|_| server.submit(gen.next_request())).collect();
        assert_eq!(server.shard_map().loads(), &[3, 3], "least-load placement balances");
        for rx in rxs {
            rx.recv().unwrap();
        }
        // A later routing decision sees the drained completions.
        let rx = server.submit(gen.next_request());
        assert_eq!(server.shard_map().len(), 1);
        rx.recv().unwrap();
        server.shutdown();
    }

    /// Block on one response receiver while pumping the supervisor, so
    /// fault recovery can run while the test waits. Panics (rather than
    /// hanging CI) if nothing arrives within the deadline — and a
    /// disconnect is a test failure by definition: supervision
    /// guarantees every sink exactly one terminal message.
    fn recv_supervised(server: &mut Server, rx: &Receiver<Response>) -> Response {
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        loop {
            server.supervise();
            match rx.recv_timeout(std::time::Duration::from_millis(2)) {
                Ok(resp) => return resp,
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    assert!(std::time::Instant::now() < deadline, "sink starved for 30s");
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    panic!("sink disconnected without a terminal response")
                }
            }
        }
    }

    #[test]
    fn dead_worker_sinks_get_terminal_errors_not_disconnects() {
        use crate::runtime::fault::{FaultInjector, FaultPlan};
        // One worker, engine dies at its second launch, respawn
        // disabled: every in-flight request must degrade to an explicit
        // error response — no hung or disconnected clients.
        let inj = FaultInjector::new(FaultPlan::Nth(2));
        let factory = {
            let inj = inj.clone();
            move || inj.wrap(MockEngine::new())
        };
        let mut server = Server::start(vec![factory], BatchPolicy::default());
        server.set_max_restarts(0);
        let rxs: Vec<_> = (0..4u64)
            .map(|id| {
                server.submit(Request { id, prompt: vec![1, 2, 3], max_new_tokens: 8 })
            })
            .collect();
        for rx in &rxs {
            let resp = recv_supervised(&mut server, rx);
            assert!(resp.is_error(), "expected terminal error, got {resp:?}");
            assert!(
                rx.try_recv().is_err(),
                "exactly one terminal message per sink"
            );
        }
        let stats = server.resilience();
        assert_eq!(stats.workers_down, 1);
        assert_eq!(stats.worker_restarts, 0);
        assert_eq!(stats.requests_failed, 4);
        assert_eq!(inj.faults_injected(), 1);
        assert!(!server.shard_map().has_live());
        server.shutdown();
    }

    #[test]
    fn fail_once_respawns_within_cap_and_completes_bit_identical() {
        use crate::runtime::fault::{FaultInjector, FaultPlan};
        let reqs: Vec<Request> = (0..6u64)
            .map(|id| Request {
                id,
                prompt: vec![3, 1, 4, 1, 5, 9],
                max_new_tokens: 10 + id as usize % 3,
            })
            .collect();
        let baseline: Vec<Vec<i32>> = {
            let (mut resps, _) =
                serve_all(|| Ok(MockEngine::new()), BatchPolicy::default(), reqs.clone()).unwrap();
            resps.sort_by_key(|r| r.id);
            resps.into_iter().map(|r| r.tokens).collect()
        };

        let inj = FaultInjector::new(FaultPlan::Once(3));
        let factory = {
            let inj = inj.clone();
            move || inj.wrap(MockEngine::new())
        };
        let mut server = Server::start(vec![factory], BatchPolicy::default());
        let rxs: Vec<_> = reqs.into_iter().map(|r| server.submit(r)).collect();
        let mut resps: Vec<Response> =
            rxs.iter().map(|rx| recv_supervised(&mut server, rx)).collect();
        resps.sort_by_key(|r| r.id);
        for (resp, want) in resps.iter().zip(&baseline) {
            assert!(resp.error.is_none(), "recoverable request failed: {:?}", resp.error);
            assert_eq!(&resp.tokens, want, "request {} diverged across the fault", resp.id);
        }
        let stats = server.resilience();
        assert_eq!(stats.workers_down, 1, "one death");
        assert_eq!(stats.worker_restarts, 1, "one respawn, within the default cap");
        assert_eq!(stats.requests_failed, 0);
        assert!(
            stats.requests_salvaged + stats.requests_reprefilled_on_fault >= 1,
            "the in-flight work was re-routed, not discarded: {stats:?}"
        );
        assert_eq!(inj.faults_injected(), 1);
        assert!(server.shard_map().has_live(), "the shard is serving again");
        server.shutdown();
    }

    #[test]
    fn construction_failure_routes_around_the_phantom_shard() {
        use crate::runtime::fault::{FaultInjector, FaultPlan};
        // Shard 0 can never build its engine; shard 1 is healthy.
        // Every request must still complete (re-routed), and the dead
        // shard must leave the routing map.
        let mk = |plan: FaultPlan| {
            let inj = FaultInjector::new(plan);
            let f = {
                let inj = inj.clone();
                move || inj.wrap(MockEngine::new())
            };
            (inj, f)
        };
        let (bad_inj, bad) = mk(FaultPlan::Construct(u64::MAX));
        let (_good_inj, good) = mk(FaultPlan::Construct(0));
        let mut server = Server::start(vec![bad, good], BatchPolicy::default());
        server.set_max_restarts(0);
        let rxs: Vec<_> = (0..6u64)
            .map(|id| {
                server.submit(Request { id, prompt: vec![2, 7, 1], max_new_tokens: 5 })
            })
            .collect();
        for rx in &rxs {
            let resp = recv_supervised(&mut server, rx);
            assert!(resp.error.is_none(), "healthy shard must absorb the load: {resp:?}");
            assert_eq!(resp.tokens.len(), 5);
        }
        assert!(bad_inj.faults_injected() >= 1);
        assert!(server.shard_map().is_dead(0));
        assert!(!server.shard_map().is_dead(1));
        // New submits never touch the phantom shard.
        let rx = server.submit(Request { id: 99, prompt: vec![4], max_new_tokens: 2 });
        assert_eq!(server.shard_map().shard_of(99), Some(1));
        assert!(recv_supervised(&mut server, &rx).error.is_none());
        server.shutdown();
    }

    #[test]
    fn construction_retry_succeeds_within_restart_budget() {
        use crate::runtime::fault::{FaultInjector, FaultPlan};
        // First construction fails, the respawn's retry builds cleanly.
        let inj = FaultInjector::new(FaultPlan::Construct(1));
        let factory = {
            let inj = inj.clone();
            move || inj.wrap(MockEngine::new())
        };
        let mut server = Server::start(vec![factory], BatchPolicy::default());
        let rxs: Vec<_> = (0..3u64)
            .map(|id| {
                server.submit(Request { id, prompt: vec![1, 2], max_new_tokens: 4 })
            })
            .collect();
        for rx in &rxs {
            let resp = recv_supervised(&mut server, rx);
            assert!(resp.error.is_none(), "{resp:?}");
            assert_eq!(resp.tokens.len(), 4);
        }
        let stats = server.resilience();
        assert_eq!(stats.workers_down, 1);
        assert_eq!(stats.worker_restarts, 1);
        assert_eq!(inj.constructions(), 2, "failed build plus the successful retry");
        server.shutdown();
    }

    #[test]
    fn retry_budget_exhaustion_is_an_explicit_error() {
        use crate::runtime::fault::{FaultInjector, FaultPlan};
        // The engine dies on its first launch of *every* incarnation:
        // requests keep getting salvaged and re-routed until their
        // replay budget runs out, then fail terminally — never an
        // infinite loop, never a dropped sink.
        let inj = FaultInjector::new(FaultPlan::Nth(1));
        let factory = {
            let inj = inj.clone();
            move || inj.wrap(MockEngine::new())
        };
        let mut server = Server::start(vec![factory], BatchPolicy::default());
        server.set_max_restarts(8);
        server.set_max_replays(2);
        let rx = server.submit(Request { id: 0, prompt: vec![5, 5], max_new_tokens: 4 });
        let resp = recv_supervised(&mut server, &rx);
        assert!(resp.is_error(), "{resp:?}");
        assert!(
            resp.error.as_deref().unwrap_or("").contains("retry budget")
                || resp.error.as_deref().unwrap_or("").contains("no healthy worker"),
            "unexpected terminal reason: {:?}",
            resp.error
        );
        assert_eq!(server.resilience().requests_failed, 1);
        assert!(inj.faults_injected() >= 2, "the fault was actually replayed");
        server.shutdown();
    }

    #[test]
    fn server_trace_reconciles_and_latency_merges_across_workers() {
        use crate::obs;
        let probe = MockEngine::new();
        let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
        let factories: Vec<fn() -> anyhow::Result<MockEngine>> =
            vec![|| Ok(MockEngine::new()), || Ok(MockEngine::new())];
        let mut server = Server::start(factories, BatchPolicy::default());
        let mut gen = WorkloadGen::new(31, vocab, plen, 2, 6);
        let rxs: Vec<_> = (0..8).map(|_| server.submit(gen.next_request())).collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().error.is_none());
        }
        // Spans: router Routed + worker lifecycle, one Completed each.
        let events = server.trace();
        let snap = server.traffic();
        obs::reconcile(&events, &snap).unwrap();
        let spans = obs::assemble_spans(&events);
        assert_eq!(spans.len(), 8);
        for sp in &spans {
            assert_eq!(sp.terminal().map(|e| e.name()), Some("completed"));
        }
        // Server-wide latency pools both workers' histograms exactly.
        let lat = server.latency();
        assert_eq!(lat.ttft_us.count(), 8);
        assert_eq!(lat.total_ticks.count(), 8);
        assert!(lat.total_us.percentile(0.99) >= lat.ttft_us.percentile(0.5));
        // The drain was exact-once: a second window is empty.
        assert!(server.trace().is_empty());
        server.shutdown();
    }

    #[test]
    fn dead_worker_trace_and_counters_survive_into_server_totals() {
        use crate::obs::{self, TraceEvent};
        use crate::runtime::fault::{FaultInjector, FaultPlan};
        // Engine dies once mid-serve; after respawn + salvage the full
        // window (dead incarnation included) still reconciles against
        // the server-wide counters, and every request has exactly one
        // terminal event.
        let inj = FaultInjector::new(FaultPlan::Once(3));
        let factory = {
            let inj = inj.clone();
            move || inj.wrap(MockEngine::new())
        };
        let mut server = Server::start(vec![factory], BatchPolicy::default());
        let rxs: Vec<_> = (0..5u64)
            .map(|id| {
                server.submit(Request { id, prompt: vec![1, 2, 3], max_new_tokens: 6 })
            })
            .collect();
        for rx in &rxs {
            let resp = recv_supervised(&mut server, rx);
            assert!(resp.error.is_none(), "{resp:?}");
        }
        assert_eq!(inj.faults_injected(), 1);
        let events = server.trace();
        assert!(
            events.iter().any(|r| matches!(r.event, TraceEvent::Fault)),
            "the dead incarnation's Fault record survived"
        );
        assert!(
            events.iter().any(|r| matches!(r.event, TraceEvent::Salvaged { .. })),
            "salvaged flights are marked in the trace"
        );
        let snap = server.traffic();
        assert_eq!(snap.requests_completed, 5, "dead worker's completions preserved");
        obs::reconcile(&events, &snap).unwrap();
        server.shutdown();
    }

    /// Spin (pumping supervision) until `shard` is retired; panics
    /// instead of hanging if the death never lands.
    fn wait_retired(server: &mut Server, shard: usize) {
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        while !server.shard_map().is_dead(shard) {
            server.supervise();
            assert!(std::time::Instant::now() < deadline, "shard {shard} never retired");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }

    #[test]
    fn submit_to_reroutes_off_a_retired_shard() {
        use crate::runtime::fault::{FaultInjector, FaultPlan};
        // Shard 0 can never construct its engine and respawn is
        // disabled, so it retires permanently; shard 1 is healthy.
        let mk = |plan: FaultPlan| {
            let inj = FaultInjector::new(plan);
            move || inj.wrap(MockEngine::new())
        };
        let mut server = Server::start(
            vec![mk(FaultPlan::Construct(u64::MAX)), mk(FaultPlan::Construct(0))],
            BatchPolicy::default(),
        );
        server.set_max_restarts(0);
        wait_retired(&mut server, 0);
        // A submit pinned onto the retired shard must be re-routed to a
        // live shard *at placement time* — not after bouncing off the
        // tombstone and burning an orphan round-trip.
        let rx = server.submit_to(Request { id: 7, prompt: vec![1, 2, 3], max_new_tokens: 4 }, 0);
        assert_eq!(
            server.shard_map().shard_of(7),
            Some(1),
            "pinned submit validated against the dead-shard mask"
        );
        let resp = recv_supervised(&mut server, &rx);
        assert!(resp.error.is_none(), "{resp:?}");
        assert_eq!(resp.tokens.len(), 4);
        server.shutdown();
    }

    #[test]
    fn force_migrate_refuses_a_retired_target() {
        use crate::runtime::fault::{FaultInjector, FaultPlan};
        // Shard 1 dies at construction (tombstone keeps its mailbox
        // open — the Attach send would still succeed); shard 0 serves.
        let mk = |plan: FaultPlan| {
            let inj = FaultInjector::new(plan);
            move || inj.wrap(MockEngine::new())
        };
        let mut server = Server::start(
            vec![mk(FaultPlan::Construct(0)), mk(FaultPlan::Construct(u64::MAX))],
            BatchPolicy::default(),
        );
        server.set_max_restarts(0);
        wait_retired(&mut server, 1);
        // Long generation keeps the request migratable while we probe.
        let rx =
            server.submit(Request { id: 3, prompt: vec![5, 1, 2], max_new_tokens: 4000 });
        assert_eq!(server.shard_map().shard_of(3), Some(0));
        for _ in 0..64 {
            assert!(
                !server.force_migrate(3, 1),
                "migration onto a retired shard must be refused up front"
            );
            assert_ne!(
                server.shard_map().shard_of(3),
                Some(1),
                "placement must never land on a retired shard"
            );
            assert_eq!(
                server.shard_map().loads()[1],
                0,
                "tracked load must never land on a retired shard"
            );
        }
        let resp = recv_supervised(&mut server, &rx);
        assert!(resp.error.is_none(), "{resp:?}");
        assert_eq!(resp.tokens.len(), 4000);
        server.shutdown();
    }

    #[test]
    fn shed_requests_reconcile_as_terminal_failed_spans() {
        use crate::obs;
        let mut server =
            Server::start(vec![|| Ok(MockEngine::new())], BatchPolicy::default());
        let rx1 = server.submit(Request { id: 0, prompt: vec![1, 2], max_new_tokens: 3 });
        server.record_admitted(0);
        let shed1 = server.shed_request(1, 2, "admission: batch share exhausted");
        assert!(shed1.is_error(), "shed returns the terminal error response");
        assert_eq!(shed1.id, 1);
        let rx2 = server.submit(Request { id: 2, prompt: vec![3, 4], max_new_tokens: 2 });
        server.record_admitted(0);
        // An out-of-range class still counts toward the total.
        let shed2 = server.shed_request(3, 9, "bogus class");
        assert!(shed2.is_error());
        assert!(recv_supervised(&mut server, &rx1).error.is_none());
        assert!(recv_supervised(&mut server, &rx2).error.is_none());

        let t = server.traffic();
        assert_eq!(t.requests_shed, 2);
        assert_eq!(t.shed_by_class, [0, 0, 1]);
        assert_eq!(t.admitted_by_class, [2, 0, 0]);
        assert_eq!(t.requests_completed, 2);
        // Shed requests appear in the lifecycle trace as Submit+Failed
        // spans and the whole window still reconciles exactly.
        let events = server.trace();
        obs::reconcile(&events, &t).unwrap();
        let spans = obs::assemble_spans(&events);
        assert_eq!(spans.len(), 4);
        for sp in &spans {
            let terminal = sp.terminal().map(|e| e.name());
            if sp.seq == 1 || sp.seq == 3 {
                assert_eq!(terminal, Some("failed"), "shed span {} terminal", sp.seq);
            } else {
                assert_eq!(terminal, Some("completed"));
            }
        }
        server.shutdown();
    }
}
