//! Threaded serving front-end: a request router feeding one or more
//! scheduler workers over channels (std threads — the vendored crate
//! set has no tokio; see DESIGN.md §4). Each worker runs the
//! continuous-batching tick loop ([`Scheduler::tick`]): one mixed
//! engine call per tick, decode rows plus prefill chunks under the
//! policy's token budget.

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;

use anyhow::Result;

use crate::planner::{Planner, PlanSpec};
use crate::runtime::engine::Executor;

use super::batcher::BatchPolicy;
use super::metrics::TrafficSnapshot;
use super::request::{Request, Response};
use super::scheduler::{Scheduler, StatePath};

enum Msg {
    Submit(Request, Sender<Response>),
    Report(Sender<String>),
    Traffic(Sender<TrafficSnapshot>),
    Shutdown,
}

/// One worker: a scheduler on its own thread.
struct Worker {
    tx: Sender<Msg>,
    handle: JoinHandle<()>,
    /// Requests routed to this worker (router-side load estimate).
    routed: u64,
}

/// The router/server: owns the workers, routes by least-load.
pub struct Server {
    workers: Vec<Worker>,
}

impl Server {
    /// Start with one worker per engine *factory*. Each worker
    /// constructs its engine on its own thread (PJRT handles are not
    /// `Send`). Multiple workers model the paper's leader/worker split:
    /// the router is the leader, each PJRT engine a worker.
    pub fn start<E, F>(factories: Vec<F>, policy: BatchPolicy) -> Server
    where
        E: Executor,
        F: FnOnce() -> anyhow::Result<E> + Send + 'static,
    {
        Server::start_planned(factories, policy, PlanSpec::default())
    }

    /// Start with an explicit plan-selection policy (each worker gets
    /// its own [`Planner`] built from the spec — plan caches and dwell
    /// state are per-worker, like the engine itself).
    pub fn start_planned<E, F>(factories: Vec<F>, policy: BatchPolicy, spec: PlanSpec) -> Server
    where
        E: Executor,
        F: FnOnce() -> anyhow::Result<E> + Send + 'static,
    {
        let workers = factories
            .into_iter()
            .map(|factory| {
                let (tx, rx) = channel::<Msg>();
                let pol = policy.clone();
                let sp = spec.clone();
                let handle = std::thread::spawn(move || match factory() {
                    Ok(engine) => worker_loop(engine, pol, sp, rx),
                    Err(e) => eprintln!("coordinator: engine construction failed: {e}"),
                });
                Worker { tx, handle, routed: 0 }
            })
            .collect();
        Server { workers }
    }

    /// Route a request to the least-loaded worker; returns the response
    /// channel.
    pub fn submit(&mut self, req: Request) -> Receiver<Response> {
        let (tx, rx) = channel();
        let w = self
            .workers
            .iter_mut()
            .min_by_key(|w| w.routed)
            .expect("at least one worker");
        w.routed += 1;
        let _ = w.tx.send(Msg::Submit(req, tx));
        rx
    }

    /// Collect metrics reports from all workers.
    pub fn reports(&self) -> Vec<String> {
        self.workers
            .iter()
            .filter_map(|w| {
                let (tx, rx) = channel();
                w.tx.send(Msg::Report(tx)).ok()?;
                rx.recv().ok()
            })
            .collect()
    }

    /// Aggregate the state-traffic and plan counters across all workers
    /// (counters sum; the resident gauge sums over workers too, since
    /// each worker owns its own arena, as does each planner).
    pub fn traffic(&self) -> TrafficSnapshot {
        let mut total = TrafficSnapshot::default();
        for w in &self.workers {
            let (tx, rx) = channel();
            if w.tx.send(Msg::Traffic(tx)).is_err() {
                continue;
            }
            if let Ok(t) = rx.recv() {
                total.bytes_gathered += t.bytes_gathered;
                total.bytes_scattered += t.bytes_scattered;
                total.state_bytes_resident += t.state_bytes_resident;
                total.padded_rows += t.padded_rows;
                total.plan_switches += t.plan_switches;
                for (a, b) in total.ticks_per_plan.iter_mut().zip(&t.ticks_per_plan) {
                    *a += b;
                }
                for (a, b) in total.plan_dwell_hist.iter_mut().zip(&t.plan_dwell_hist) {
                    *a += b;
                }
                total.predicted_cycles += t.predicted_cycles;
                total.predicted_bytes += t.predicted_bytes;
                total.modeled_cycles += t.modeled_cycles;
                total.modeled_bytes += t.modeled_bytes;
            }
        }
        total
    }

    /// Graceful shutdown: drains in-flight work first.
    pub fn shutdown(self) {
        for w in &self.workers {
            let _ = w.tx.send(Msg::Shutdown);
        }
        for w in self.workers {
            let _ = w.handle.join();
        }
    }
}

fn worker_loop<E: Executor>(engine: E, policy: BatchPolicy, spec: PlanSpec, rx: Receiver<Msg>) {
    let mut sched =
        Scheduler::with_planner(engine, policy, StatePath::Resident, Planner::new(spec));
    let mut sinks: std::collections::BTreeMap<u64, Sender<Response>> =
        std::collections::BTreeMap::new();
    let mut shutting_down = false;
    loop {
        // Drain the mailbox without blocking while work is in flight.
        loop {
            match rx.try_recv() {
                Ok(Msg::Submit(req, sink)) => {
                    sinks.insert(req.id, sink);
                    if let Err(e) = sched.submit(req) {
                        eprintln!("coordinator: rejected request: {e}");
                    }
                }
                Ok(Msg::Report(tx)) => {
                    let _ = tx.send(sched.metrics().report());
                }
                Ok(Msg::Traffic(tx)) => {
                    let _ = tx.send(sched.metrics().traffic_snapshot());
                }
                Ok(Msg::Shutdown) => shutting_down = true,
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => shutting_down = true,
            }
            if shutting_down {
                break;
            }
        }
        if shutting_down && sched.pending() == 0 {
            return;
        }

        match sched.tick() {
            Ok((done, progressed)) => {
                for resp in done {
                    if let Some(sink) = sinks.remove(&resp.id) {
                        let _ = sink.send(resp);
                    }
                }
                if !progressed {
                    if shutting_down && sched.pending() == 0 {
                        return;
                    }
                    // Idle: block briefly for new work.
                    match rx.recv_timeout(std::time::Duration::from_millis(1)) {
                        Ok(Msg::Submit(req, sink)) => {
                            sinks.insert(req.id, sink);
                            if let Err(e) = sched.submit(req) {
                                eprintln!("coordinator: rejected request: {e}");
                            }
                        }
                        Ok(Msg::Report(tx)) => {
                            let _ = tx.send(sched.metrics().report());
                        }
                        Ok(Msg::Traffic(tx)) => {
                            let _ = tx.send(sched.metrics().traffic_snapshot());
                        }
                        Ok(Msg::Shutdown) => shutting_down = true,
                        Err(_) => {}
                    }
                }
            }
            Err(e) => {
                eprintln!("coordinator: engine error: {e}");
                // Fail-stop for this worker: report and exit.
                return;
            }
        }
    }
}

/// Convenience: serve a fixed batch of requests to completion on one
/// executor and return (responses, metrics report).
pub fn serve_all<E, F>(
    factory: F,
    policy: BatchPolicy,
    reqs: Vec<Request>,
) -> Result<(Vec<Response>, String)>
where
    E: Executor,
    F: FnOnce() -> anyhow::Result<E> + Send + 'static,
{
    let mut server = Server::start(vec![factory], policy);
    let sinks: Vec<Receiver<Response>> =
        reqs.into_iter().map(|r| server.submit(r)).collect();
    let mut responses = Vec::new();
    for rx in sinks {
        responses.push(rx.recv()?);
    }
    let report = server.reports().join("\n");
    server.shutdown();
    Ok((responses, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::WorkloadGen;
    use crate::runtime::mock::MockEngine;

    #[test]
    fn serve_all_round_trips() {
        let probe = MockEngine::new();
        let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
        let mut gen = WorkloadGen::new(9, vocab, plen, 2, 5);
        let reqs: Vec<Request> = (0..10).map(|_| gen.next_request()).collect();
        let want: Vec<(u64, usize)> =
            reqs.iter().map(|r| (r.id, r.max_new_tokens)).collect();
        let (mut resps, report) =
            serve_all(|| Ok(MockEngine::new()), BatchPolicy::default(), reqs).unwrap();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), want.len());
        for (resp, (id, n)) in resps.iter().zip(&want) {
            assert_eq!(resp.id, *id);
            assert_eq!(resp.tokens.len(), *n);
        }
        assert!(report.contains("requests=10"), "{report}");
    }

    #[test]
    fn multi_worker_routing_balances() {
        let probe = MockEngine::new();
        let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
        let factories: Vec<fn() -> anyhow::Result<MockEngine>> =
            vec![|| Ok(MockEngine::new()), || Ok(MockEngine::new())];
        let mut server = Server::start(factories, BatchPolicy::default());
        let mut gen = WorkloadGen::new(11, vocab, plen, 2, 2);
        let rxs: Vec<_> = (0..8).map(|_| server.submit(gen.next_request())).collect();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.tokens.len(), 2);
        }
        let reports = server.reports();
        assert_eq!(reports.len(), 2);
        // Both workers saw traffic.
        for r in &reports {
            assert!(!r.contains("requests=0"), "{r}");
        }
        server.shutdown();
    }

    #[test]
    fn shutdown_with_no_work_is_clean() {
        let server = Server::start(vec![|| Ok(MockEngine::new())], BatchPolicy::default());
        server.shutdown();
    }

    #[test]
    fn traffic_aggregates_across_workers_and_is_zero_on_mock() {
        // The mock engine is fused, so the resident hot path moves no
        // state bytes no matter how many workers serve the load.
        let probe = MockEngine::new();
        let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
        let factories: Vec<fn() -> anyhow::Result<MockEngine>> =
            vec![|| Ok(MockEngine::new()), || Ok(MockEngine::new())];
        let mut server = Server::start(factories, BatchPolicy::default());
        let mut gen = WorkloadGen::new(5, vocab, plen, 2, 3);
        let rxs: Vec<_> = (0..6).map(|_| server.submit(gen.next_request())).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let t = server.traffic();
        assert_eq!(t.bytes_gathered, 0);
        assert_eq!(t.bytes_scattered, 0);
        assert_eq!(t.padded_rows, 0);
        assert_eq!(t.state_bytes_resident, 0, "all slots released after drain");
        // Plan counters aggregate across both workers: every tick ran
        // under some plan, and the mock modeled its cost.
        assert!(t.ticks_per_plan.iter().sum::<u64>() > 0);
        assert!(t.modeled_cycles > 0);
        assert!(t.predicted_cycles > 0);
        server.shutdown();
    }

    #[test]
    fn static_plan_spec_serves_identically() {
        use crate::fusion::FusionVariant;
        use crate::planner::{PlanChoice, PlanSpec};
        let probe = MockEngine::new();
        let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
        let serve = |spec: PlanSpec| {
            let mut server = Server::start_planned(
                vec![|| Ok(MockEngine::new())],
                BatchPolicy::default(),
                spec,
            );
            let mut gen = WorkloadGen::new(8, vocab, plen, 2, 4);
            let rxs: Vec<_> = (0..6).map(|_| server.submit(gen.next_request())).collect();
            let mut toks: Vec<Vec<i32>> = rxs.into_iter().map(|rx| rx.recv().unwrap().tokens).collect();
            toks.sort();
            let t = server.traffic();
            server.shutdown();
            (toks, t)
        };
        let (adaptive_tokens, _) = serve(PlanSpec::Adaptive);
        let (static_tokens, t) =
            serve(PlanSpec::Static(PlanChoice::Variant(FusionVariant::RIOnly)));
        assert_eq!(adaptive_tokens, static_tokens);
        // A static spec runs every tick under the one plan.
        let ri = PlanChoice::Variant(FusionVariant::RIOnly).index();
        assert_eq!(t.ticks_per_plan.iter().sum::<u64>(), t.ticks_per_plan[ri]);
        assert_eq!(t.plan_switches, 0);
    }
}
