//! The serving scheduler: drives **continuous batching with chunked
//! prefill** over an [`Executor`], carrying per-sequence recurrent
//! state between steps.
//!
//! One `tick()` = one *mixed* engine invocation ([`Action::Mixed`],
//! chosen by the [`Batcher`] policy): every running sequence advances
//! one decode token, and waiting prompts contribute prefill chunks up
//! to the per-tick token budget. A sequence's prompt may span many
//! ticks before its first sampled token; its partial prefill state
//! lives in the [`StateManager`] between chunks. Greedy (argmax)
//! sampling.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::engine::{argmax_rows, Executor};

use super::batcher::{Action, Batcher, BatchPolicy, ChunkPlan};
use super::metrics::Metrics;
use super::request::{InFlight, Request, Response};
use super::state::StateManager;

/// Single-threaded scheduling core (wrapped by [`super::server::Server`]
/// for threaded serving).
pub struct Scheduler<E: Executor> {
    engine: E,
    batcher: Batcher,
    states: StateManager,
    /// Submitted, prompt not fully prefilled (prefill cursor < prompt
    /// length; partial state in `states` once the first chunk ran).
    waiting: BTreeMap<u64, InFlight>,
    /// Prefilled, generating.
    running: BTreeMap<u64, InFlight>,
    /// Round-robin cursor over running sequences, for ticks whose token
    /// budget covers only part of the decode set.
    decode_rr: usize,
    metrics: Metrics,
}

impl<E: Executor> Scheduler<E> {
    pub fn new(engine: E, policy: BatchPolicy) -> Scheduler<E> {
        let m = engine.manifest();
        let states = StateManager::new(
            m.n_layer,
            m.d_inner * (m.d_conv - 1),
            m.d_inner * m.d_state,
        );
        Scheduler {
            engine,
            batcher: Batcher::new(policy),
            states,
            waiting: BTreeMap::new(),
            running: BTreeMap::new(),
            decode_rr: 0,
            metrics: Metrics::new(),
        }
    }

    /// Accept a request. Any non-empty prompt length is served — the
    /// batcher splits it into chunks of at most `chunk_tokens`.
    pub fn submit(&mut self, req: Request) -> Result<()> {
        anyhow::ensure!(!req.prompt.is_empty(), "empty prompt");
        anyhow::ensure!(req.max_new_tokens >= 1, "must generate at least one token");
        self.batcher.enqueue(req.id, req.prompt.len());
        self.waiting.insert(req.id, InFlight::new(req));
        Ok(())
    }

    pub fn pending(&self) -> usize {
        self.waiting.len() + self.running.len()
    }

    /// Sequences currently generating.
    pub fn running(&self) -> usize {
        self.running.len()
    }

    /// Sequences whose prompt is not fully prefilled yet.
    pub fn waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn manifest(&self) -> &crate::runtime::artifact::Manifest {
        self.engine.manifest()
    }

    /// One scheduling step. Returns completed responses (possibly
    /// empty). `Ok(false)` means there was nothing to do.
    pub fn tick(&mut self) -> Result<(Vec<Response>, bool)> {
        match self.batcher.next_action(self.running.len()) {
            Action::Idle => Ok((Vec::new(), false)),
            Action::Mixed { chunks, decode } => {
                let decode_ids = self.pick_decode_rows(decode);
                let done = self.do_mixed(&chunks, &decode_ids)?;
                // Cursors advance only after the engine call succeeds
                // (fail-stop keeps batcher and scheduler consistent).
                self.batcher.commit(&chunks);
                Ok((done, true))
            }
        }
    }

    /// Run until every submitted request completes; returns responses in
    /// completion order.
    pub fn run_until_drained(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        while self.pending() > 0 {
            let (done, progressed) = self.tick()?;
            out.extend(done);
            if !progressed && self.pending() > 0 {
                // Unreachable with a normalized policy (budget ≥ 1 and
                // at least one slot always lets the queue head move);
                // kept as a guard against pathological custom policies.
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
        }
        Ok(out)
    }

    fn vocab(&self) -> usize {
        self.engine.manifest().vocab
    }

    /// The next `n` running sequences in round-robin order, so a token
    /// budget smaller than the running set still reaches every sequence
    /// across consecutive ticks.
    fn pick_decode_rows(&mut self, n: usize) -> Vec<u64> {
        let keys: Vec<u64> = self.running.keys().copied().collect();
        if keys.is_empty() || n == 0 {
            return Vec::new();
        }
        let n = n.min(keys.len());
        let start = self.decode_rr % keys.len();
        let ids = (0..n).map(|i| keys[(start + i) % keys.len()]).collect();
        self.decode_rr = (start + n) % keys.len();
        ids
    }

    /// One mixed engine invocation: `chunks` prefill-chunk rows followed
    /// by one decode row per id in `decode_ids`.
    fn do_mixed(&mut self, chunks: &[ChunkPlan], decode_ids: &[u64]) -> Result<Vec<Response>> {
        let batch = chunks.len() + decode_ids.len();
        assert!(batch > 0, "empty mixed action");
        let mut lens = Vec::with_capacity(batch);
        let mut tokens = Vec::new();
        // Per-row state source: None = fresh (zero state).
        let mut row_state: Vec<Option<u64>> = Vec::with_capacity(batch);
        for ch in chunks {
            let fl = self.waiting.get(&ch.id).expect("waiting entry for chunk");
            assert_eq!(fl.prefill_pos, ch.start, "scheduler cursor mismatch for seq {}", ch.id);
            tokens.extend_from_slice(&fl.req.prompt[ch.start..ch.start + ch.len]);
            lens.push(ch.len);
            row_state.push(if ch.start == 0 { None } else { Some(ch.id) });
        }
        for &id in decode_ids {
            tokens.push(*self.running[&id].generated.last().expect("running seq has a token"));
            lens.push(1);
            row_state.push(Some(id));
        }

        let (conv, ssm) = self.states.gather_rows(&row_state);
        let out = self.engine.step_mixed(&lens, &tokens, &conv, &ssm)?;

        let chunk_tokens: usize = chunks.iter().map(|c| c.len).sum();
        if !chunks.is_empty() {
            self.metrics.record_prefill(chunks.len(), chunk_tokens);
        }
        if !decode_ids.is_empty() {
            self.metrics.record_decode(decode_ids.len());
        }
        self.metrics.record_tick(
            chunk_tokens + decode_ids.len(),
            self.batcher.policy().token_budget,
            self.waiting.len(),
        );

        let next = argmax_rows(&out.logits, self.vocab());
        let now = Instant::now();
        let mut completed = Vec::new();

        // Prefill-chunk rows: carry partial state, or sample the first
        // token when the chunk completes the prompt.
        for (b, ch) in chunks.iter().enumerate() {
            if ch.last {
                let mut fl = self.waiting.remove(&ch.id).expect("waiting entry");
                fl.prefill_pos += ch.len;
                fl.first_token = Some(now);
                fl.generated.push(next[b]);
                self.metrics.record_decode(1); // the prefill-produced token
                if fl.done() {
                    self.states.release(ch.id); // drop any partial state
                    let resp = fl.finish();
                    self.metrics.record_completion(resp.ttft, resp.total);
                    completed.push(resp);
                } else {
                    self.states
                        .install_from_batch(ch.id, batch, b, &out.conv_state, &out.ssm_state);
                    self.running.insert(ch.id, fl);
                }
            } else {
                let fl = self.waiting.get_mut(&ch.id).expect("waiting entry");
                fl.prefill_pos += ch.len;
                self.states
                    .install_from_batch(ch.id, batch, b, &out.conv_state, &out.ssm_state);
            }
        }

        // Decode rows.
        for (i, &id) in decode_ids.iter().enumerate() {
            let b = chunks.len() + i;
            let fl = self.running.get_mut(&id).expect("running entry");
            fl.generated.push(next[b]);
            if fl.done() {
                let fl = self.running.remove(&id).unwrap();
                self.states.release(id);
                let resp = fl.finish();
                self.metrics.record_completion(resp.ttft, resp.total);
                completed.push(resp);
            } else {
                self.states.install_from_batch(id, batch, b, &out.conv_state, &out.ssm_state);
            }
        }
        Ok(completed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::WorkloadGen;
    use crate::runtime::mock::MockEngine;

    fn sched() -> Scheduler<MockEngine> {
        Scheduler::new(MockEngine::new(), BatchPolicy::default())
    }

    #[test]
    fn single_request_completes() {
        let mut s = sched();
        let m = s.manifest();
        let (vocab, plen) = (m.vocab, m.prefill_len);
        let mut gen = WorkloadGen::new(1, vocab, plen, 3, 3);
        s.submit(gen.next_request()).unwrap();
        let out = s.run_until_drained().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens.len(), 3);
        assert!(out[0].total >= out[0].ttft);
        assert_eq!(s.metrics().requests_completed, 1);
    }

    #[test]
    fn batched_equals_solo_generation() {
        // The same request must generate the same tokens whether served
        // alone or continuously batched with others — state gather/
        // scatter, chunk boundaries and mixed rows must not leak across
        // sequences.
        let m = MockEngine::new();
        let (vocab, plen) = (m.manifest().vocab, m.manifest().prefill_len);
        let mut gen = WorkloadGen::new(42, vocab, plen, 4, 4).with_prompt_range(1, 3 * plen);
        let reqs: Vec<_> = (0..5).map(|_| gen.next_request()).collect();

        // Solo runs.
        let mut solo_tokens = Vec::new();
        for r in &reqs {
            let mut s = sched();
            s.submit(r.clone()).unwrap();
            let out = s.run_until_drained().unwrap();
            solo_tokens.push(out[0].tokens.clone());
        }

        // Batched run.
        let mut s = sched();
        for r in &reqs {
            s.submit(r.clone()).unwrap();
        }
        let mut out = s.run_until_drained().unwrap();
        out.sort_by_key(|r| r.id);
        for (resp, solo) in out.iter().zip(&solo_tokens) {
            assert_eq!(&resp.tokens, solo, "request {} diverged under batching", resp.id);
        }
    }

    #[test]
    fn staggered_submission_with_varied_lengths() {
        let mut s = sched();
        let m = s.manifest();
        let (vocab, plen) = (m.vocab, m.prefill_len);
        let mut gen = WorkloadGen::new(7, vocab, plen, 1, 9).with_prompt_range(1, 2 * plen);
        let mut expected = 0usize;
        let mut responses = Vec::new();
        for wave in 0..4 {
            for _ in 0..=wave {
                let r = gen.next_request();
                expected += 1;
                s.submit(r).unwrap();
            }
            // Interleave some ticks between waves.
            for _ in 0..3 {
                let (done, _) = s.tick().unwrap();
                responses.extend(done);
            }
        }
        responses.extend(s.run_until_drained().unwrap());
        assert_eq!(responses.len(), expected);
        for r in &responses {
            assert!(!r.tokens.is_empty());
        }
        // All state slots were released.
        assert_eq!(s.pending(), 0);
    }

    #[test]
    fn rejects_empty_prompt_and_zero_generation() {
        let mut s = sched();
        let bad = Request { id: 1, prompt: vec![], max_new_tokens: 1 };
        assert!(s.submit(bad).is_err());
        let bad = Request { id: 2, prompt: vec![0; 4], max_new_tokens: 0 };
        assert!(s.submit(bad).is_err());
    }

    #[test]
    fn metrics_track_tokens() {
        let mut s = sched();
        let m = s.manifest();
        let mut gen = WorkloadGen::new(3, m.vocab, m.prefill_len, 5, 5);
        for _ in 0..3 {
            s.submit(gen.next_request()).unwrap();
        }
        s.run_until_drained().unwrap();
        assert_eq!(s.metrics().tokens_generated, 15);
        assert!(s.metrics().mean_occupancy() > 0.0);
    }

    #[test]
    fn long_prompt_spans_many_ticks_before_first_token() {
        // chunk_tokens=4, token_budget=8: a 32-token prompt needs 8
        // chunk ticks before its first sampled token, and the prefill
        // cursor advances monotonically through them.
        let policy = BatchPolicy {
            chunk_tokens: 4,
            token_budget: 8,
            ..BatchPolicy::default()
        };
        let mut s = Scheduler::new(MockEngine::new(), policy);
        let prompt: Vec<i32> = (0..32).map(|x| x % 17).collect();
        s.submit(Request { id: 9, prompt, max_new_tokens: 2 }).unwrap();
        let mut prefill_ticks = 0;
        while s.metrics().requests_completed == 0 {
            let before = s.metrics().prefill_tokens;
            s.tick().unwrap();
            if s.metrics().prefill_tokens > before {
                prefill_ticks += 1;
            }
        }
        assert_eq!(prefill_ticks, 8);
        assert_eq!(s.metrics().prefill_tokens, 32);
        assert_eq!(s.metrics().max_tick_tokens, 4);
    }

    #[test]
    fn chunked_prefill_interleaves_with_decode() {
        // While a long prompt is mid-prefill, already-running sequences
        // keep decoding every tick — no full-tick stalls.
        let policy = BatchPolicy {
            chunk_tokens: 4,
            token_budget: 8,
            ..BatchPolicy::default()
        };
        let m = MockEngine::new();
        let vocab = m.manifest().vocab;
        let mut s = Scheduler::new(m, policy);
        // A short prompt that finishes prefill immediately and then
        // decodes for a long time.
        s.submit(Request { id: 1, prompt: vec![3, 1, 4], max_new_tokens: 40 }).unwrap();
        s.tick().unwrap(); // seq 1 prefills and starts running
        // Now a long prompt floods in.
        let prompt: Vec<i32> = (0..48).map(|x| x % vocab as i32).collect();
        s.submit(Request { id: 2, prompt, max_new_tokens: 1 }).unwrap();
        // Every subsequent tick must advance seq 1 by exactly one token
        // while seq 2's prefill progresses.
        for _ in 0..12 {
            let gen_before = s.metrics().tokens_generated;
            let pre_before = s.metrics().prefill_tokens;
            s.tick().unwrap();
            assert!(s.metrics().tokens_generated > gen_before, "decode stalled");
            if s.metrics().requests_completed == 0 {
                assert!(s.metrics().prefill_tokens > pre_before, "prefill stalled");
            }
        }
    }
}
