//! The serving scheduler: drives **continuous batching with chunked
//! prefill** over an [`Executor`], keeping per-sequence recurrent state
//! **resident in engine layout** between steps.
//!
//! One `tick()` = one *mixed* engine invocation ([`Action::Mixed`],
//! chosen by the [`Batcher`] policy): every running sequence advances
//! one decode token, and waiting prompts contribute prefill chunks up
//! to the per-tick token budget. A sequence's prompt may span many
//! ticks before its first sampled token; its partial prefill state
//! lives in the [`StateArena`] between chunks. Greedy (argmax)
//! sampling.
//!
//! ## Hot-path memory discipline
//!
//! The default path ([`StatePath::Resident`]) admits each sequence to a
//! stable arena row once and then launches the arena's slabs straight
//! through one typed [`LaunchSpec`] per tick ([`Executor::launch`]):
//! the engine advances every row in place and writes logits into a
//! persistent [`Workspace`]. All per-tick staging (segments, tokens,
//! sampled tokens, round-robin scratch) lives in buffers retained
//! across ticks, so a steady-state decode tick — unchanged batch
//! membership — performs **zero gather/scatter copies and zero heap
//! allocation** on a fused engine. Membership changes touch only the
//! affected rows (a zeroing admit or a free-list release).
//!
//! [`StatePath::Reference`] keeps the pre-residency data path —
//! gather packed copies, launch over them with identity rows, install
//! the outputs back — bit-identical in tokens and counters, as the
//! equivalence baseline for tests and for the deterministic
//! traffic-counter comparison (`bytes_gathered` / `bytes_scattered`
//! in [`Metrics`]).
//!
//! Which path a plain [`Scheduler::new`] runs, which fusion plans the
//! planner may pick, and whether launches carry a
//! [`Donation::DonateInPlace`] annotation are all **negotiated from
//! the engine's [`EngineCaps`]** at construction — nothing is probed
//! and nothing is hardcoded.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use crate::obs::{TraceEvent, TraceRecord, TraceRing, DEFAULT_TRACE_CAP, WORKER_SEQ};
use crate::planner::{Planner, PlanSpec, WorkloadFeatures};
use crate::runtime::engine::{argmax_rows_into, Executor, Workspace};
use crate::runtime::{Donation, EngineCaps, LaunchSpec, MixedBatch, Phase, Segment, StateSlabs};

use super::batcher::{Action, Batcher, BatchPolicy, ChunkPlan};
use super::metrics::{LatencyReport, Metrics};
use super::request::{InFlight, Request, Response};
use super::shard::MigrationPacket;
use super::snapshot::{SnapshotCache, SnapshotConfig};
use super::state::{SlotHandle, StateArena};

/// How the scheduler moves recurrent state between ticks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StatePath {
    /// Zero-copy (default for in-place-capable engines): state stays
    /// resident in the arena and the engine advances arena rows in
    /// place through the per-tick launch.
    Resident,
    /// Pre-residency baseline: gather packed copies per tick, launch
    /// over them with identity rows, install the outputs back. Kept
    /// for equivalence tests, as the traffic-counter reference, and as
    /// the fallback for engines whose caps disclaim in-place state.
    Reference,
}

/// Single-threaded scheduling core (wrapped by [`super::server::Server`]
/// for threaded serving).
pub struct Scheduler<E: Executor> {
    engine: E,
    batcher: Batcher,
    states: StateArena,
    path: StatePath,
    /// Per-tick fusion-plan selection (static / adaptive / table; see
    /// [`crate::planner`]). The decision is made from the tick's
    /// [`WorkloadFeatures`] before the engine call and carried in the
    /// [`LaunchSpec`] on both state paths, so plan choice can never
    /// depend on — or change — the data path.
    planner: Planner,
    /// The engine's capability report, read once at construction: the
    /// planner's candidate mask, the default state path, and the
    /// per-launch [`Donation`] annotation all come from it.
    caps: EngineCaps,
    /// Persistent engine workspace: logits surface + staging buffers +
    /// traffic counters, reused every tick.
    ws: Workspace,
    /// Submitted, prompt not fully prefilled (prefill cursor < prompt
    /// length; partial state resident in `states` once the first chunk
    /// ran).
    waiting: BTreeMap<u64, InFlight>,
    /// Prefilled, generating.
    running: BTreeMap<u64, InFlight>,
    /// Round-robin cursor over running sequences, for ticks whose token
    /// budget covers only part of the decode set.
    decode_rr: usize,
    /// Set after an engine error. The resident path advances arena rows
    /// *in place*, so a failed tick may leave state partially ahead of
    /// the batcher cursors — retrying would silently corrupt outputs.
    /// Once poisoned, every tick fails fast; the scheduler must be
    /// discarded — but not necessarily its *work*: `salvage()` consumes
    /// it and exports every in-flight sequence (see
    /// `server::worker_loop`, which salvages on tick error).
    poisoned: bool,
    /// The arena rows the **failing** launch touched (chunk rows plus
    /// decode rows of the poisoned tick). Only these rows may have been
    /// advanced in place by the partial launch; every other resident
    /// row is still bit-exact, which is what makes `salvage()` sound.
    suspect: Vec<u64>,
    /// Resident state bytes on *other* shards of the sharded arena
    /// (pushed by the server's gauge sync), so the planner's
    /// [`WorkloadFeatures`] see the server-wide residency, not just
    /// this worker's slice.
    remote_resident: u64,
    /// Session-keyed snapshot cache: completed session-tagged requests
    /// export their arena row here; follow-up turns attach it and
    /// prefill only their new tokens. Owned by this scheduler thread
    /// (sessions pin to one shard), never crosses the channel.
    snapshots: SnapshotCache,
    /// seq id → session id for in-flight session-tagged requests, so
    /// the completion hook knows which cache key to store under.
    session_of: BTreeMap<u64, u64>,
    metrics: Metrics,
    /// Bounded request-lifecycle trace ring, stamped with the
    /// deterministic tick clock. Pre-allocated at construction and
    /// drop-oldest on overflow ([`TraceRing::events_dropped`] counts),
    /// so tracing never allocates on the steady-state decode tick.
    trace: TraceRing,
    // Per-tick staging, retained across ticks so the steady-state
    // decode tick allocates nothing.
    segs_buf: Vec<Segment>,
    tokens_buf: Vec<i32>,
    row_state_buf: Vec<Option<u64>>,
    next_buf: Vec<i32>,
    rr_scratch: Vec<u64>,
    decode_ids_buf: Vec<u64>,
}

/// Re-anchor a migrated/salvaged flight's tick stamps to the receiving
/// worker's clock. Tick clocks are per worker, so a delta across two
/// clocks would be meaningless (or underflow); after re-stamping, tick
/// latencies measure on-shard scheduling delay. Wall-clock stamps
/// (`submitted` / `first_token`) are untouched — end-to-end wall
/// latency still spans the migration.
fn restamp_ticks(fl: &mut InFlight, now: u64) {
    fl.submitted_tick = now;
    if fl.first_token_tick.is_some() {
        fl.first_token_tick = Some(now);
    }
    fl.last_token_tick = now;
}

impl<E: Executor> Scheduler<E> {
    /// Default construction: the state path follows the engine's
    /// capability report (`in_place_state` ⇒ zero-copy residency,
    /// otherwise the packed reference path) instead of being
    /// hardcoded.
    pub fn new(engine: E, policy: BatchPolicy) -> Scheduler<E> {
        Scheduler::with_planner_auto(engine, policy, Planner::new(PlanSpec::default()))
    }

    /// Construct with an explicit state path (tests / benchmarks).
    pub fn with_path(engine: E, policy: BatchPolicy, path: StatePath) -> Scheduler<E> {
        Scheduler::with_planner(engine, policy, path, Planner::new(PlanSpec::default()))
    }

    /// Construct with an explicit plan policy, the state path chosen
    /// from the engine's capability report (what the server workers
    /// use).
    pub fn with_planner_auto(engine: E, policy: BatchPolicy, planner: Planner) -> Scheduler<E> {
        let path = if engine.caps().in_place_state {
            StatePath::Resident
        } else {
            StatePath::Reference
        };
        Scheduler::with_planner(engine, policy, path, planner)
    }

    /// Fully-explicit constructor: state path plus plan policy.
    pub fn with_planner(
        engine: E,
        policy: BatchPolicy,
        path: StatePath,
        mut planner: Planner,
    ) -> Scheduler<E> {
        // Capability negotiation: the engine *declares* which fusion
        // plans it can execute and the planner masks its candidate set
        // accordingly — a misconfiguration surfaces here, at
        // construction, never as a mid-serve engine error (the old
        // register_variant trial-and-error is gone).
        let caps = engine.caps();
        planner.apply_caps(&caps);
        let m = engine.manifest();
        let batcher = Batcher::new(policy);
        // The batcher admits at most `max_running` state-holding
        // sequences, so the arena never grows on the hot path.
        let states = StateArena::new(
            m.n_layer,
            m.d_inner * (m.d_conv - 1),
            m.d_inner * m.d_state,
            batcher.policy().max_running,
        );
        Scheduler {
            engine,
            batcher,
            states,
            path,
            planner,
            caps,
            ws: Workspace::new(),
            waiting: BTreeMap::new(),
            running: BTreeMap::new(),
            decode_rr: 0,
            poisoned: false,
            suspect: Vec::new(),
            remote_resident: 0,
            snapshots: SnapshotCache::new(SnapshotConfig::default()),
            session_of: BTreeMap::new(),
            metrics: Metrics::new(),
            trace: TraceRing::new(DEFAULT_TRACE_CAP),
            segs_buf: Vec::new(),
            tokens_buf: Vec::new(),
            row_state_buf: Vec::new(),
            next_buf: Vec::new(),
            rr_scratch: Vec::new(),
            decode_ids_buf: Vec::new(),
        }
    }

    /// Accept a request. Any non-empty prompt length is served — the
    /// batcher splits it into chunks of at most `chunk_tokens`.
    pub fn submit(&mut self, req: Request) -> Result<()> {
        self.submit_session(req, None)
    }

    /// Accept a request, optionally tagged with a session id.
    ///
    /// A session-tagged request does two things a plain one does not:
    /// on completion, its final recurrent state is exported to the
    /// [`SnapshotCache`] keyed by the session; and at submit time the
    /// cache is consulted — if the stored history is a strict prefix of
    /// this prompt, the snapshot is attached via the arena's
    /// `attach_row` splice (one counted copy, `snapshot_bytes_restored`)
    /// and the prefill cursor starts *after* the history, so only the
    /// new tokens run through the engine (`prefill_tokens_skipped`).
    /// Token outputs are identical to a full prefill: the cached row is
    /// bit-exactly the state the skipped history would rebuild, and the
    /// chunked-prefill machinery already resumes from a nonzero cursor
    /// (the same splice migration attaches use).
    ///
    /// Duplicate in-flight ids are rejected: admitting one would make
    /// `StateArena::admit` silently re-zero the resident row of the
    /// original mid-flight (see `admit`'s idempotence contract), which
    /// corrupts its remaining generation.
    pub fn submit_session(&mut self, req: Request, session: Option<u64>) -> Result<()> {
        anyhow::ensure!(!req.prompt.is_empty(), "empty prompt");
        anyhow::ensure!(req.max_new_tokens >= 1, "must generate at least one token");
        anyhow::ensure!(
            !self.waiting.contains_key(&req.id) && !self.running.contains_key(&req.id),
            "request id {} is already in flight (duplicate submit would re-zero its \
             resident state row)",
            req.id
        );
        let id = req.id;
        self.trace_push(id, TraceEvent::Submit);
        if let Some(session) = session {
            self.session_of.insert(id, session);
            if let Some(hit) = self.snapshots.lookup(session, &req.prompt) {
                let h = hit.history_len;
                let bytes = hit.payload.state_bytes();
                self.states.attach_row(id, &hit.payload.conv, &hit.payload.ssm);
                self.metrics.record_snapshot_hit(
                    bytes,
                    h as u64,
                    self.states.resident_bytes(),
                );
                self.trace_push(id, TraceEvent::SnapshotHit { tokens_skipped: h as u64 });
                self.mirror_snapshot_cache();
                self.batcher.enqueue_at(id, req.prompt.len(), h);
                let mut fl = InFlight::new(req);
                fl.prefill_pos = h;
                fl.submitted_tick = self.metrics.ticks;
                self.waiting.insert(id, fl);
                return Ok(());
            }
        }
        self.batcher.enqueue(id, req.prompt.len());
        let mut fl = InFlight::new(req);
        fl.submitted_tick = self.metrics.ticks;
        self.waiting.insert(id, fl);
        Ok(())
    }

    /// Copy-on-write session fork: register `child` as a session whose
    /// next submit attaches `parent`'s snapshot. O(1) in state bytes —
    /// the payload is refcounted and shared; each child's attach is the
    /// one counted copy. Returns `false` when the parent has no
    /// snapshot (or the child key is taken).
    pub fn fork_session(&mut self, parent: u64, child: u64) -> bool {
        let ok = self.snapshots.fork(parent, child);
        if ok {
            self.metrics.record_snapshot_fork();
            self.mirror_snapshot_cache();
        }
        ok
    }

    /// Replace the snapshot cache's LRU byte budget and re-enforce it
    /// immediately (`0` disables session caching).
    pub fn set_snapshot_budget(&mut self, bytes: u64) {
        self.snapshots.set_budget(bytes);
        self.mirror_snapshot_cache();
    }

    /// The session snapshot cache (tests / diagnostics).
    pub fn snapshot_cache(&self) -> &SnapshotCache {
        &self.snapshots
    }

    /// Mirror the cache's unique-bytes gauge and eviction total into
    /// the metrics after any mutation.
    fn mirror_snapshot_cache(&mut self) {
        self.metrics
            .record_snapshot_cache(self.snapshots.resident_bytes(), self.snapshots.evictions());
    }

    /// Completion hook: export a finishing session-tagged request's
    /// state to the snapshot cache, keyed by its session. Runs *before*
    /// the arena row is released. The stored history is everything the
    /// state has actually consumed: the (possibly reprefill-extended)
    /// prompt plus the fed-back generated tokens — the final sampled
    /// token was never fed through the engine, so it is excluded; a
    /// follow-up turn that includes it in its prompt prefills it as a
    /// new token, which keeps snapshot attaches token-identical to full
    /// prefills.
    fn snapshot_on_completion(&mut self, seq: u64, fl: &InFlight) {
        let Some(session) = self.session_of.remove(&seq) else {
            return;
        };
        let Some((conv, ssm)) = self.states.snapshot(seq) else {
            return;
        };
        let k = fl.generated.len();
        let mut history = fl.req.prompt.clone();
        if k > 0 && fl.prompt_replayed < k - 1 {
            history.extend_from_slice(&fl.generated[fl.prompt_replayed..k - 1]);
        }
        self.snapshots.store(session, history, conv, ssm);
        self.metrics.record_snapshot_store();
        self.mirror_snapshot_cache();
    }

    pub fn pending(&self) -> usize {
        self.waiting.len() + self.running.len()
    }

    /// Sequences currently generating.
    pub fn running(&self) -> usize {
        self.running.len()
    }

    /// Sequences whose prompt is not fully prefilled yet.
    pub fn waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Record one lifecycle event, stamped with this worker's tick
    /// clock and shard index. O(1), no allocation (the ring is
    /// pre-allocated; overflow drops the oldest record and counts it).
    fn trace_push(&mut self, seq: u64, event: TraceEvent) {
        self.trace.push(TraceRecord {
            seq,
            tick: self.metrics.ticks,
            shard: self.states.shard() as u32,
            event,
        });
    }

    /// Drain the trace ring into a fresh vector, oldest first. The
    /// cumulative drop counter survives the drain.
    pub fn take_trace(&mut self) -> Vec<TraceRecord> {
        let mut out = Vec::with_capacity(self.trace.len());
        self.trace.drain_into(&mut out);
        out
    }

    /// Drain the trace ring into `out` (appends, oldest first) —
    /// allocation-free when `out` has capacity.
    pub fn drain_trace_into(&mut self, out: &mut Vec<TraceRecord>) {
        self.trace.drain_into(out);
    }

    /// How many trace records the bounded ring has dropped (cumulative
    /// over the scheduler's lifetime; drains do not reset it).
    pub fn trace_dropped(&self) -> u64 {
        self.trace.events_dropped()
    }

    /// Copy of the mergeable latency histograms (tick + wall units);
    /// `LatencyReport::merge` pools them exactly across workers.
    pub fn latency_report(&self) -> LatencyReport {
        self.metrics.latency_report()
    }

    /// The deterministic tick clock trace records are stamped with.
    pub fn tick_count(&self) -> u64 {
        self.metrics.ticks
    }

    /// Which state path this scheduler runs.
    pub fn path(&self) -> StatePath {
        self.path
    }

    /// The engine's capability report (read once at construction).
    pub fn caps(&self) -> EngineCaps {
        self.caps
    }

    /// The per-tick plan selector (tests / diagnostics).
    pub fn planner(&self) -> &Planner {
        &self.planner
    }

    /// The resident-state arena (tests / diagnostics).
    pub fn state_arena(&self) -> &StateArena {
        &self.states
    }

    /// Assign this scheduler's shard index in the sharded arena (the
    /// server sets one per worker; defaults to 0).
    pub fn set_shard(&mut self, shard: usize) {
        self.states.set_shard(shard);
    }

    /// The globally stable `(shard, row)` handle of a resident
    /// sequence's state.
    pub fn slot_of(&self, seq: u64) -> Option<SlotHandle> {
        self.states.handle_of(seq)
    }

    /// Update the resident-bytes gauge of the *other* shards (server
    /// gauge sync), consulted by [`Scheduler::global_resident_bytes`].
    pub fn set_remote_resident_bytes(&mut self, bytes: u64) {
        self.remote_resident = bytes;
    }

    /// Server-wide resident state bytes: this shard's arena gauge plus
    /// the last-synced remote gauge — the value the planner's
    /// [`WorkloadFeatures`] carry each tick.
    pub fn global_resident_bytes(&self) -> u64 {
        self.states.resident_bytes() + self.remote_resident
    }

    /// **Migration detach**: splice an in-flight sequence out of this
    /// worker — its bookkeeping plus its resident state rows — without
    /// disturbing any other sequence's residency (the steady-state
    /// zero-copy tick path is untouched; detach runs between ticks).
    ///
    /// Detachable: decode-phase (running) sequences, and mid-prefill
    /// sequences whose partial state exists (cursor > 0). Returns
    /// `None` for anything else — completed, unknown, pre-state, or
    /// when this scheduler is poisoned (its resident state cannot be
    /// trusted, so it must not be exported).
    pub fn detach(&mut self, seq: u64) -> Option<MigrationPacket> {
        if self.poisoned {
            return None;
        }
        let flight = if self.running.contains_key(&seq) {
            self.running.remove(&seq).expect("checked")
        } else if self.waiting.get(&seq).map_or(false, |fl| fl.prefill_pos > 0) {
            let fl = self.waiting.remove(&seq).expect("checked");
            let (_, pos) = self.batcher.remove(seq).expect("waiting seq has a batcher job");
            debug_assert_eq!(pos, fl.prefill_pos, "batcher cursor mirrors InFlight");
            fl
        } else {
            return None;
        };
        let from = self.states.handle_of(seq).expect("in-flight seq holds state");
        let (conv, ssm) =
            self.states.detach_row(seq).expect("in-flight seq has resident state");
        // A migrated request completes on another worker, whose cache
        // never saw this session — drop the tag here rather than leave
        // a stale entry (the session simply misses on its next turn).
        self.session_of.remove(&seq);
        self.metrics.record_migration_out(self.states.resident_bytes());
        let own = self.states.shard() as u32;
        self.trace_push(seq, TraceEvent::MigrationOut { shard: own });
        Some(MigrationPacket { flight, from, conv, ssm })
    }

    /// **Migration attach** (the sharded design's payoff): install a
    /// detached sequence's state into this shard's arena and resume it
    /// exactly where the source worker stopped — decode-phase requests
    /// rejoin the running set, mid-prefill ones rejoin the prefill
    /// queue at their cursor. One `state_bytes_per_seq` transfer,
    /// counted as `bytes_migrated`; never a re-prefill.
    ///
    /// A malformed packet is **rejected, not unwound**: the packet
    /// comes from another worker over a channel, so a corrupt one must
    /// not crash this worker (the old behaviour was an `assert!` panic
    /// deep in `Batcher::enqueue_at`, or — worse — a decode-phase
    /// packet with an empty `generated` buffer joining the running set
    /// and panicking mid-tick). Validation runs *before* any state is
    /// touched, so `Err` returns the packet unchanged and leaves this
    /// scheduler exactly as it was; the server falls back to
    /// [`Scheduler::attach_reprefill`], which rebuilds state from
    /// tokens and doesn't trust the payload.
    pub fn attach(&mut self, p: MigrationPacket) -> Result<(), MigrationPacket> {
        let seq = p.seq();
        let (conv_len, ssm_len) = self.states.payload_shape();
        let valid = !self.running.contains_key(&seq)
            && !self.waiting.contains_key(&seq)
            && !p.flight.req.prompt.is_empty()
            && p.flight.prefill_pos <= p.flight.req.prompt.len()
            && (!p.decode_phase() || !p.flight.generated.is_empty())
            && p.conv.len() == conv_len
            && p.ssm.len() == ssm_len;
        if !valid {
            return Err(p);
        }
        let decode_phase = p.decode_phase();
        let bytes = p.state_bytes();
        let from_shard = p.from.shard as u32;
        self.states.attach_row(seq, &p.conv, &p.ssm);
        self.metrics
            .record_migration_in(bytes, decode_phase, self.states.resident_bytes());
        self.trace_push(seq, TraceEvent::MigrationIn { shard: from_shard });
        let mut flight = p.flight;
        // Tick clocks are per worker: re-anchor the flight's stamps to
        // the local clock so tick latencies stay non-negative and
        // measure on-shard delay (wall-clock stamps are untouched).
        restamp_ticks(&mut flight, self.metrics.ticks);
        if decode_phase {
            self.running.insert(seq, flight);
        } else {
            self.batcher
                .enqueue_at(seq, flight.req.prompt.len(), flight.prefill_pos);
            self.waiting.insert(seq, flight);
        }
        Ok(())
    }

    /// **Re-prefill attach**: the pre-sharding baseline, kept so the
    /// counter gates can price what migration replaces. The packet's
    /// state payload is discarded; the already-processed tokens (whole
    /// prompt plus generated suffix for decode-phase requests, the
    /// prefilled prefix for mid-prefill ones) are replayed through the
    /// engine as a fresh prefill. Token outputs are identical — the
    /// replayed history rebuilds the exact state, and the final chunk
    /// re-samples the same pending token — but the cost lands in
    /// `reprefill_tokens` instead of `bytes_migrated`.
    pub fn attach_reprefill(&mut self, p: MigrationPacket) {
        let replayed = p.reprefill_cost_tokens() as u64;
        let decode_phase = p.decode_phase();
        let from_shard = p.from.shard as u32;
        let mut flight = p.flight;
        let seq = flight.req.id;
        if decode_phase {
            // State after k generated tokens reflects prompt + g1..gk−1
            // (gk is the pending decode input), so that is the history
            // to replay; the completing chunk re-samples gk. Append
            // only the suffix a previous re-prefill has not already
            // folded into the prompt (`prompt_replayed`), else the
            // replayed history would duplicate tokens. k == 0 — a
            // decode-phase packet with nothing generated yet (cursor at
            // prompt end, first token pending) — has nothing to fold
            // back: `k - 1` would underflow usize and panic, so just
            // replay the prompt.
            let k = flight.generated.len();
            if k > 0 {
                flight
                    .req
                    .prompt
                    .extend_from_slice(&flight.generated[flight.prompt_replayed..k - 1]);
                flight.prompt_replayed = k - 1;
                flight.generated.truncate(k - 1);
            }
        }
        flight.prefill_pos = 0;
        self.metrics
            .record_migration_in(0, false, self.states.resident_bytes());
        self.metrics.record_reprefill(replayed);
        self.trace_push(seq, TraceEvent::MigrationIn { shard: from_shard });
        self.trace_push(seq, TraceEvent::Replayed { tokens: replayed });
        restamp_ticks(&mut flight, self.metrics.ticks);
        self.batcher.enqueue(seq, flight.req.prompt.len());
        self.waiting.insert(seq, flight);
    }

    /// True once an engine error has poisoned this scheduler (every
    /// further `tick`/`detach` refuses; `salvage` is the way out).
    pub fn poisoned(&self) -> bool {
        self.poisoned
    }

    /// The rows the poisoning launch touched (empty when not poisoned).
    pub fn suspect_rows(&self) -> &[u64] {
        &self.suspect
    }

    /// **Salvage a poisoned scheduler**: consume it and export every
    /// in-flight sequence as a [`MigrationPacket`], so a worker death
    /// forfeits at most the rows the failing launch actually touched —
    /// discarding everything is the documented floor, not the only
    /// option.
    ///
    /// Soundness: flight bookkeeping (generated tokens, prefill
    /// cursors) advances only *after* a successful launch, and
    /// `Batcher::commit` runs only on success, so on the failed tick
    /// every flight's token record is exact. Resident state is advanced
    /// **in place** by the engine, so only the rows named in the
    /// failing launch (recorded in `suspect_rows`) may hold partially
    /// advanced state. Accordingly:
    ///
    /// - **Untouched rows with resident state** export as
    ///   state-carrying packets — valid for [`Scheduler::attach`] on a
    ///   healthy shard, one counted copy, no replay.
    /// - **Suspect rows** (and queued rows with no state yet) export as
    ///   token-only packets (empty payload). These deliberately fail
    ///   `attach`'s shape validation and fall through to
    ///   [`Scheduler::attach_reprefill`], which rebuilds state from
    ///   tokens and never trusts the payload. An unstarted row replays
    ///   zero tokens — resubmission is free.
    ///
    /// Packets are returned in ascending sequence order (running rows
    /// first, then waiting). No metrics are recorded — this scheduler
    /// is being consumed; the receiving shard counts the attach.
    pub fn salvage(mut self) -> Vec<MigrationPacket> {
        use std::collections::BTreeSet;
        let suspect: BTreeSet<u64> = self.suspect.iter().copied().collect();
        let ids: Vec<u64> = self
            .running
            .keys()
            .chain(self.waiting.keys())
            .copied()
            .collect();
        let mut out = Vec::with_capacity(ids.len());
        for seq in ids {
            let flight = match self.running.remove(&seq) {
                Some(fl) => fl,
                None => {
                    self.batcher.remove(seq);
                    self.waiting.remove(&seq).expect("id came from waiting")
                }
            };
            let trusted = !suspect.contains(&seq);
            let packet = match (trusted, self.states.handle_of(seq)) {
                (true, Some(from)) => {
                    let (conv, ssm) = self
                        .states
                        .detach_row(seq)
                        .expect("resident handle implies a detachable row");
                    MigrationPacket { flight, from, conv, ssm }
                }
                _ => MigrationPacket {
                    flight,
                    // Placeholder provenance for a token-only packet;
                    // attach() rejects it on payload shape and the
                    // re-prefill path never reads `from`.
                    from: SlotHandle { shard: self.states.shard(), row: 0 },
                    conv: Vec::new(),
                    ssm: Vec::new(),
                },
            };
            self.session_of.remove(&seq);
            out.push(packet);
        }
        out
    }

    pub fn manifest(&self) -> &crate::runtime::artifact::Manifest {
        self.engine.manifest()
    }

    /// One scheduling step. Returns completed responses (possibly
    /// empty). `Ok(false)` means there was nothing to do.
    pub fn tick(&mut self) -> Result<(Vec<Response>, bool)> {
        anyhow::ensure!(
            !self.poisoned,
            "scheduler poisoned by a prior engine error (resident state may \
             be partially advanced); discard this scheduler"
        );
        match self.batcher.next_action(self.running.len()) {
            Action::Idle => Ok((Vec::new(), false)),
            Action::Mixed { chunks, decode } => {
                self.pick_decode_rows(decode);
                // Temporarily move the id buffer out so `do_mixed` can
                // borrow the rest of `self` (restored below; the empty
                // stand-in does not allocate).
                let decode_ids = std::mem::take(&mut self.decode_ids_buf);
                let result = self.do_mixed(&chunks, &decode_ids);
                self.decode_ids_buf = decode_ids;
                let done = match result {
                    Ok(done) => done,
                    Err(e) => {
                        // The engine may have advanced some arena rows
                        // in place before failing; nothing here can be
                        // retried. Poison the scheduler so no caller
                        // feeds already-consumed tokens to
                        // already-advanced state — but record exactly
                        // which rows the failing launch touched, so
                        // `salvage()` can still export everything else
                        // with its state intact.
                        self.poisoned = true;
                        self.suspect.clear();
                        self.suspect.extend(chunks.iter().map(|c| c.id));
                        self.suspect.extend(self.decode_ids_buf.iter().copied());
                        self.trace_push(WORKER_SEQ, TraceEvent::Fault);
                        return Err(e);
                    }
                };
                // Cursors advance only after the engine call succeeds,
                // so batcher and scheduler stay consistent on success —
                // and a failure poisons the scheduler (above) rather
                // than pretending the tick is retryable.
                self.batcher.commit(&chunks);
                Ok((done, true))
            }
        }
    }

    /// Run until every submitted request completes; returns responses in
    /// completion order.
    pub fn run_until_drained(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        while self.pending() > 0 {
            let (done, progressed) = self.tick()?;
            out.extend(done);
            if !progressed && self.pending() > 0 {
                // Unreachable with a normalized policy (budget ≥ 1 and
                // at least one slot always lets the queue head move);
                // kept as a guard against pathological custom policies.
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
        }
        Ok(out)
    }

    fn vocab(&self) -> usize {
        self.engine.manifest().vocab
    }

    /// Fill `decode_ids_buf` with the next `n` running sequences in
    /// round-robin order, so a token budget smaller than the running
    /// set still reaches every sequence across consecutive ticks.
    /// Allocation-free once the scratch buffers are warm.
    fn pick_decode_rows(&mut self, n: usize) {
        self.decode_ids_buf.clear();
        if n == 0 || self.running.is_empty() {
            return;
        }
        self.rr_scratch.clear();
        self.rr_scratch.extend(self.running.keys());
        let k = self.rr_scratch.len();
        let n = n.min(k);
        let start = self.decode_rr % k;
        for i in 0..n {
            self.decode_ids_buf.push(self.rr_scratch[(start + i) % k]);
        }
        self.decode_rr = (start + n) % k;
    }

    /// One mixed engine invocation: `chunks` prefill-chunk rows followed
    /// by one decode row per id in `decode_ids`, launched as a single
    /// typed [`LaunchSpec`].
    fn do_mixed(&mut self, chunks: &[ChunkPlan], decode_ids: &[u64]) -> Result<Vec<Response>> {
        let batch = chunks.len() + decode_ids.len();
        assert!(batch > 0, "empty mixed action");
        self.tokens_buf.clear();
        self.segs_buf.clear();
        for ch in chunks {
            let fl = self.waiting.get(&ch.id).expect("waiting entry for chunk");
            assert_eq!(fl.prefill_pos, ch.start, "scheduler cursor mismatch for seq {}", ch.id);
            self.tokens_buf.extend_from_slice(&fl.req.prompt[ch.start..ch.start + ch.len]);
        }
        for &id in decode_ids {
            self.tokens_buf
                .push(*self.running[&id].generated.last().expect("running seq has a token"));
        }

        // Build the tick's segments. The declared phase is what the
        // scheduler *knows* — a chunk at cursor 0 starts from the
        // zeroed row it was just admitted to (`PrefillFirst`), later
        // chunks carry state (`PrefillCont`), unit rows are decode
        // steps — so engines never re-derive it by scanning state
        // memory. Reference-path rows are the packed batch indices;
        // resident rows come from the arena (fresh rows admitted —
        // zeroed, free-list — up front, everything else already
        // resident, so unchanged batch membership rebuilds the same
        // plan with zero copies).
        let mut ref_bufs: Option<(Vec<f32>, Vec<f32>)> = None;
        match self.path {
            StatePath::Resident => {
                for ch in chunks {
                    let row = if ch.start == 0 {
                        self.states.admit(ch.id)
                    } else {
                        self.states
                            .row_of(ch.id)
                            .expect("mid-prefill chunk has resident state")
                    };
                    self.segs_buf.push(Segment { len: ch.len, row, phase: chunk_phase(ch) });
                }
                for &id in decode_ids {
                    let row = self.states.row_of(id).expect("decode row has resident state");
                    self.segs_buf.push(Segment { len: 1, row, phase: Phase::Decode });
                }
            }
            StatePath::Reference => {
                // Pre-residency data path: gather packed per-tick
                // copies (counted by the arena), launch over them with
                // identity rows, install back below. Routes through the
                // same persistent workspace so the engine's own staging
                // traffic is counted too.
                self.row_state_buf.clear();
                for (b, ch) in chunks.iter().enumerate() {
                    self.row_state_buf.push(if ch.start == 0 { None } else { Some(ch.id) });
                    self.segs_buf.push(Segment { len: ch.len, row: b, phase: chunk_phase(ch) });
                }
                for (i, &id) in decode_ids.iter().enumerate() {
                    self.row_state_buf.push(Some(id));
                    self.segs_buf.push(Segment {
                        len: 1,
                        row: chunks.len() + i,
                        phase: Phase::Decode,
                    });
                }
                ref_bufs = Some(self.states.gather_rows(&self.row_state_buf));
            }
        }

        // The validated batch view — one construction per tick, over
        // the retained buffers (no allocation once warm; the distinct-
        // rows check is the engine's corruption guard).
        let view = MixedBatch::new(&self.segs_buf, &self.tokens_buf)?;

        // Select this tick's fusion plan from the same typed view the
        // engine will see (single-token chunk rows classify as decode
        // rows). The resident gauge is the *server-wide* one — this
        // shard's arena plus the synced remote shards. Steady state
        // this is a bucket-cache lookup — no allocation, no model
        // evaluation.
        let features = WorkloadFeatures::from_batch(
            &view,
            self.global_resident_bytes(),
            self.batcher.policy().token_budget,
        );
        let decision = self.planner.decide(&features);

        let vocab = self.vocab();
        match &mut ref_bufs {
            // Resident: the arena slabs go straight into the launch —
            // donated when the engine's caps say it honours donation.
            None => {
                let donation = if self.caps.donation {
                    Donation::DonateInPlace
                } else {
                    Donation::Retain
                };
                self.engine.launch(LaunchSpec {
                    batch: view,
                    state: self.states.slabs(donation),
                    plan: Some(decision.choice),
                    ws: &mut self.ws,
                })?;
            }
            // Reference: launch over the gathered packed copies (always
            // retained — they are installed back after the call).
            Some((conv, ssm)) => {
                self.engine.launch(LaunchSpec {
                    batch: view,
                    state: StateSlabs::new(conv, ssm, batch, Donation::Retain),
                    plan: Some(decision.choice),
                    ws: &mut self.ws,
                })?;
            }
        }
        let ref_out = ref_bufs;
        argmax_rows_into(&self.ws.logits, vocab, &mut self.next_buf);

        let chunk_tokens: usize = chunks.iter().map(|c| c.len).sum();
        if !chunks.is_empty() {
            self.metrics.record_prefill(chunks.len(), chunk_tokens);
        }
        if !decode_ids.is_empty() {
            self.metrics.record_decode(decode_ids.len());
        }
        self.metrics.record_tick(
            chunk_tokens + decode_ids.len(),
            self.batcher.policy().token_budget,
            self.waiting.len(),
        );
        // All lifecycle events of this tick are stamped *after*
        // `record_tick`, so every record of tick T carries tick == T
        // (1-based, matching `Metrics::ticks`).
        let tick_now = self.metrics.ticks;
        for ch in chunks {
            self.trace_push(
                ch.id,
                TraceEvent::ChunkScheduled { chunk_len: ch.len as u32, cursor: ch.start as u32 },
            );
        }

        let now = Instant::now();
        let mut completed = Vec::new();

        // Prefill-chunk rows: carry partial state, or sample the first
        // token when the chunk completes the prompt.
        for (b, ch) in chunks.iter().enumerate() {
            if ch.last {
                let mut fl = self.waiting.remove(&ch.id).expect("waiting entry");
                fl.prefill_pos += ch.len;
                // A reprefill-migrated flight already clocked its first
                // token on the source worker — keep the original TTFT.
                if fl.first_token.is_none() {
                    fl.first_token = Some(now);
                }
                if fl.first_token_tick.is_none() {
                    fl.first_token_tick = Some(tick_now);
                    self.trace_push(ch.id, TraceEvent::FirstToken);
                }
                fl.last_token_tick = tick_now;
                fl.generated.push(self.next_buf[b]);
                self.metrics.record_decode(1); // the prefill-produced token
                if fl.done() {
                    // Reference path: completed flights normally skip
                    // the install-back, but a session snapshot needs
                    // the post-tick state in the arena first.
                    if self.session_of.contains_key(&ch.id) {
                        if let Some((conv, ssm)) = &ref_out {
                            self.states.install_from_batch(ch.id, batch, b, conv, ssm);
                        }
                    }
                    self.snapshot_on_completion(ch.id, &fl); // before the row is freed
                    self.states.release(ch.id); // free the slot
                    let resp = fl.finish();
                    self.metrics.record_completion(resp.ttft, resp.total);
                    self.metrics.record_completion_ticks(
                        fl.first_token_tick
                            .unwrap_or(tick_now)
                            .saturating_sub(fl.submitted_tick),
                        tick_now.saturating_sub(fl.submitted_tick),
                    );
                    self.trace_push(ch.id, TraceEvent::Completed);
                    completed.push(resp);
                } else {
                    if let Some((conv, ssm)) = &ref_out {
                        self.states.install_from_batch(ch.id, batch, b, conv, ssm);
                    }
                    self.running.insert(ch.id, fl);
                }
            } else {
                let fl = self.waiting.get_mut(&ch.id).expect("waiting entry");
                fl.prefill_pos += ch.len;
                if let Some((conv, ssm)) = &ref_out {
                    self.states.install_from_batch(ch.id, batch, b, conv, ssm);
                }
            }
        }

        // Decode rows. Note the borrow discipline: `fl` holds
        // `self.running`, so the per-token bookkeeping below touches
        // only *other* fields (`metrics`, `next_buf`) — field-disjoint
        // borrows — and no trace event fires on a plain decode token
        // (the steady-state tick stays event-free per sequence).
        for (i, &id) in decode_ids.iter().enumerate() {
            let b = chunks.len() + i;
            let fl = self.running.get_mut(&id).expect("running entry");
            fl.generated.push(self.next_buf[b]);
            let gap = tick_now.saturating_sub(fl.last_token_tick);
            fl.last_token_tick = tick_now;
            self.metrics.record_inter_token_ticks(gap);
            if fl.done() {
                let fl = self.running.remove(&id).expect("running entry present above");
                if self.session_of.contains_key(&id) {
                    if let Some((conv, ssm)) = &ref_out {
                        self.states.install_from_batch(id, batch, b, conv, ssm);
                    }
                }
                self.snapshot_on_completion(id, &fl); // before the row is freed
                self.states.release(id);
                let resp = fl.finish();
                self.metrics.record_completion(resp.ttft, resp.total);
                self.metrics.record_completion_ticks(
                    fl.first_token_tick
                        .unwrap_or(tick_now)
                        .saturating_sub(fl.submitted_tick),
                    tick_now.saturating_sub(fl.submitted_tick),
                );
                self.trace_push(id, TraceEvent::Completed);
                completed.push(resp);
            } else if let Some((conv, ssm)) = &ref_out {
                self.states.install_from_batch(id, batch, b, conv, ssm);
            }
        }

        // Deterministic traffic accounting: everything the arena copied
        // (reference gather/install, relocation) plus everything the
        // engine staged through the workspace (default decomposition,
        // padding). Zero on the resident path with a fused engine.
        let mut traffic = self.states.take_traffic();
        traffic.merge(self.ws.take_traffic());
        let padded = self.ws.take_padded_rows();
        self.metrics.record_traffic(traffic, self.states.resident_bytes(), padded);
        // Device-launch accounting: 1 per tick on a fused varlen
        // engine, the compiled-group count under the decomposition.
        let device_calls = self.ws.take_device_calls();
        self.metrics.record_device_calls(device_calls);

        // Plan accounting: the decision, and the engine's modeled cost
        // for executing it (zero on engines that don't model plans).
        let (modeled_cycles, modeled_bytes) = self.ws.take_modeled();
        self.metrics.record_plan(&decision, modeled_cycles, modeled_bytes);

        // The worker-scoped Launch record carries exactly what the
        // counters above just absorbed, which is what lets
        // `obs::reconcile` demand Σ Launch.device_calls ==
        // `Metrics::device_calls` (and staged bytes likewise) with no
        // slack.
        self.trace_push(
            WORKER_SEQ,
            TraceEvent::Launch {
                plan: decision.choice.index() as u8,
                device_calls,
                staged_bytes: traffic.total(),
            },
        );

        Ok(completed)
    }
}

/// The scheduler-declared [`Phase`] of one prefill chunk row: cursor 0
/// means the row was just admitted to a zeroed arena slot (or gathers
/// as zeros on the reference path), so the engine may treat it as a
/// fresh full prefill; unit chunks are decode steps, exactly as the
/// engine classifies lengths.
fn chunk_phase(ch: &ChunkPlan) -> Phase {
    if ch.len == 1 {
        Phase::Decode
    } else if ch.start == 0 {
        Phase::PrefillFirst
    } else {
        Phase::PrefillCont
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::WorkloadGen;
    use crate::planner::PlanChoice;
    use crate::runtime::mock::MockEngine;

    fn sched() -> Scheduler<MockEngine> {
        Scheduler::new(MockEngine::new(), BatchPolicy::default())
    }

    #[test]
    fn single_request_completes() {
        let mut s = sched();
        let m = s.manifest();
        let (vocab, plen) = (m.vocab, m.prefill_len);
        let mut gen = WorkloadGen::new(1, vocab, plen, 3, 3);
        s.submit(gen.next_request()).unwrap();
        let out = s.run_until_drained().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens.len(), 3);
        assert!(out[0].total >= out[0].ttft);
        assert_eq!(s.metrics().requests_completed, 1);
    }

    #[test]
    fn batched_equals_solo_generation() {
        // The same request must generate the same tokens whether served
        // alone or continuously batched with others — resident rows,
        // chunk boundaries and mixed rows must not leak across
        // sequences.
        let m = MockEngine::new();
        let (vocab, plen) = (m.manifest().vocab, m.manifest().prefill_len);
        let mut gen = WorkloadGen::new(42, vocab, plen, 4, 4).with_prompt_range(1, 3 * plen);
        let reqs: Vec<_> = (0..5).map(|_| gen.next_request()).collect();

        // Solo runs.
        let mut solo_tokens = Vec::new();
        for r in &reqs {
            let mut s = sched();
            s.submit(r.clone()).unwrap();
            let out = s.run_until_drained().unwrap();
            solo_tokens.push(out[0].tokens.clone());
        }

        // Batched run.
        let mut s = sched();
        for r in &reqs {
            s.submit(r.clone()).unwrap();
        }
        let mut out = s.run_until_drained().unwrap();
        out.sort_by_key(|r| r.id);
        for (resp, solo) in out.iter().zip(&solo_tokens) {
            assert_eq!(&resp.tokens, solo, "request {} diverged under batching", resp.id);
        }
    }

    #[test]
    fn staggered_submission_with_varied_lengths() {
        let mut s = sched();
        let m = s.manifest();
        let (vocab, plen) = (m.vocab, m.prefill_len);
        let mut gen = WorkloadGen::new(7, vocab, plen, 1, 9).with_prompt_range(1, 2 * plen);
        let mut expected = 0usize;
        let mut responses = Vec::new();
        for wave in 0..4 {
            for _ in 0..=wave {
                let r = gen.next_request();
                expected += 1;
                s.submit(r).unwrap();
            }
            // Interleave some ticks between waves.
            for _ in 0..3 {
                let (done, _) = s.tick().unwrap();
                responses.extend(done);
            }
        }
        responses.extend(s.run_until_drained().unwrap());
        assert_eq!(responses.len(), expected);
        for r in &responses {
            assert!(!r.tokens.is_empty());
        }
        // All state slots were released.
        assert_eq!(s.pending(), 0);
        assert!(s.state_arena().is_empty());
    }

    #[test]
    fn rejects_empty_prompt_and_zero_generation() {
        let mut s = sched();
        let bad = Request { id: 1, prompt: vec![], max_new_tokens: 1 };
        assert!(s.submit(bad).is_err());
        let bad = Request { id: 2, prompt: vec![0; 4], max_new_tokens: 0 };
        assert!(s.submit(bad).is_err());
    }

    #[test]
    fn metrics_track_tokens() {
        let mut s = sched();
        let m = s.manifest();
        let mut gen = WorkloadGen::new(3, m.vocab, m.prefill_len, 5, 5);
        for _ in 0..3 {
            s.submit(gen.next_request()).unwrap();
        }
        s.run_until_drained().unwrap();
        assert_eq!(s.metrics().tokens_generated, 15);
        assert!(s.metrics().mean_occupancy() > 0.0);
    }

    #[test]
    fn resident_path_moves_no_state_bytes_on_mock() {
        // The whole point of the refactor: on a fused engine, serving
        // an entire workload gathers and scatters nothing.
        let mut s = sched();
        assert_eq!(s.path(), StatePath::Resident);
        let m = s.manifest();
        let mut gen =
            WorkloadGen::new(11, m.vocab, m.prefill_len, 2, 6).with_prompt_range(1, 20);
        for _ in 0..6 {
            s.submit(gen.next_request()).unwrap();
        }
        s.run_until_drained().unwrap();
        assert_eq!(s.metrics().bytes_gathered, 0);
        assert_eq!(s.metrics().bytes_scattered, 0);
        assert_eq!(s.metrics().padded_rows, 0);
    }

    #[test]
    fn caps_pick_the_state_path_and_donation() {
        use crate::runtime::EngineCaps;
        // An in-place-capable engine gets the zero-copy resident path…
        let s = sched();
        assert_eq!(s.path(), StatePath::Resident);
        assert!(s.caps().donation);
        // …an engine that disclaims in-place state falls back to the
        // packed reference path, with no hardcoding anywhere.
        let caps = EngineCaps { in_place_state: false, ..EngineCaps::baseline() };
        let mut s = Scheduler::new(MockEngine::with_caps(caps), BatchPolicy::default());
        assert_eq!(s.path(), StatePath::Reference);
        assert!(!s.caps().donation);
        // And it still serves correctly (decomposition + gather/install).
        s.submit(Request { id: 1, prompt: vec![2, 3], max_new_tokens: 3 }).unwrap();
        let out = s.run_until_drained().unwrap();
        assert_eq!(out[0].tokens.len(), 3);
        assert!(s.metrics().bytes_gathered > 0);
    }

    #[test]
    fn fused_engine_makes_one_device_call_per_tick() {
        // The capability the whole redesign exists to expose: a
        // varlen-fused engine serves every tick in exactly one device
        // launch; the same engine with the kernel capability off pays
        // the decomposition's lockstep call count.
        use crate::runtime::EngineCaps;
        let run = |caps: EngineCaps| {
            let mut s = Scheduler::new(MockEngine::with_caps(caps), BatchPolicy::default());
            let m = s.manifest();
            let mut gen =
                WorkloadGen::new(19, m.vocab, m.prefill_len, 2, 5).with_prompt_range(2, 20);
            for _ in 0..5 {
                s.submit(gen.next_request()).unwrap();
            }
            let mut out = s.run_until_drained().unwrap();
            out.sort_by_key(|r| r.id);
            let tokens: Vec<Vec<i32>> = out.into_iter().map(|r| r.tokens).collect();
            (tokens, s.metrics().ticks, s.metrics().device_calls)
        };
        let (fused_tokens, fused_ticks, fused_calls) = run(EngineCaps::full());
        let (decomp_tokens, decomp_ticks, decomp_calls) =
            run(EngineCaps { varlen_kernel: false, ..EngineCaps::full() });
        assert_eq!(fused_tokens, decomp_tokens, "caps must not change outputs");
        assert_eq!(fused_calls, fused_ticks, "fused: exactly 1 device call per tick");
        assert_eq!(fused_ticks, decomp_ticks, "same schedule either way");
        assert!(
            decomp_calls > decomp_ticks,
            "decomposition must pay more than 1 call per tick: {decomp_calls} vs {decomp_ticks}"
        );
    }

    #[test]
    fn reference_path_counts_traffic() {
        let mut s = Scheduler::with_path(
            MockEngine::new(),
            BatchPolicy::default(),
            StatePath::Reference,
        );
        let m = s.manifest();
        let mut gen = WorkloadGen::new(11, m.vocab, m.prefill_len, 4, 6);
        for _ in 0..4 {
            s.submit(gen.next_request()).unwrap();
        }
        s.run_until_drained().unwrap();
        assert!(s.metrics().bytes_gathered > 0, "reference path must gather");
        assert!(s.metrics().bytes_scattered > 0, "reference path must scatter");
    }

    #[test]
    fn plan_choice_never_changes_tokens() {
        // The adaptive ≡ static token-output property at the scheduler
        // level: every plan spec serves the identical token streams.
        let probe = MockEngine::new();
        let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
        let run = |planner: Planner| {
            let mut s = Scheduler::with_planner(
                MockEngine::new(),
                BatchPolicy::default(),
                StatePath::Resident,
                planner,
            );
            let mut gen = WorkloadGen::new(23, vocab, plen, 2, 6).with_prompt_range(1, 40);
            for _ in 0..6 {
                s.submit(gen.next_request()).unwrap();
            }
            let mut out = s.run_until_drained().unwrap();
            out.sort_by_key(|r| r.id);
            out.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        let adaptive = run(Planner::new(PlanSpec::Adaptive));
        for choice in PlanChoice::candidates() {
            let fixed = run(Planner::new(PlanSpec::Static(choice)));
            assert_eq!(adaptive, fixed, "tokens diverged under static:{}", choice.name());
        }
    }

    #[test]
    fn scheduler_records_plan_metrics() {
        let mut s = sched();
        let m = s.manifest();
        let mut gen = WorkloadGen::new(5, m.vocab, m.prefill_len, 3, 5);
        for _ in 0..3 {
            s.submit(gen.next_request()).unwrap();
        }
        s.run_until_drained().unwrap();
        let met = s.metrics();
        let total_plan_ticks: u64 = met.ticks_per_plan.iter().sum();
        assert_eq!(total_plan_ticks, met.ticks, "every tick runs under exactly one plan");
        // The mock charges every tick with the plan's analytical cost.
        assert!(met.modeled_cycles > 0);
        assert!(met.predicted_cycles > 0);
    }

    #[test]
    fn detach_attach_resumes_decode_without_reprefill() {
        // One request decodes on shard 0 for a while, migrates to
        // shard 1, and finishes there — tokens identical to an
        // unmigrated run, zero prefill work on the target worker.
        let solo = {
            let mut s = sched();
            s.submit(Request { id: 5, prompt: vec![3, 1, 4, 1], max_new_tokens: 12 }).unwrap();
            s.run_until_drained().unwrap().remove(0).tokens
        };

        let mut a = sched();
        a.set_shard(0);
        let mut b = sched();
        b.set_shard(1);
        a.submit(Request { id: 5, prompt: vec![3, 1, 4, 1], max_new_tokens: 12 }).unwrap();
        for _ in 0..5 {
            a.tick().unwrap();
        }
        assert_eq!(a.running(), 1);
        assert_eq!(a.slot_of(5).unwrap().shard, 0);

        let p = a.detach(5).expect("running seq detaches");
        assert!(p.decode_phase());
        assert_eq!(p.from.shard, 0);
        assert_eq!(p.state_bytes(), a.state_arena().bytes_per_seq() as u64);
        assert!(a.detach(5).is_none(), "gone from the source");
        b.attach(p).unwrap();
        assert_eq!(b.slot_of(5).unwrap().shard, 1, "migration changed the handle's shard");

        let mut out = b.run_until_drained().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out.remove(0).tokens, solo, "migration changed tokens");
        // The move was counted once, on the attach side, and the
        // target worker prefilled nothing.
        assert_eq!(a.metrics().migrations_out, 1);
        assert_eq!(b.metrics().migrations, 1);
        assert_eq!(b.metrics().bytes_migrated, b.state_arena().bytes_per_seq() as u64);
        assert_eq!(b.metrics().reprefills_avoided, 1);
        assert_eq!(b.metrics().prefill_tokens, 0, "migration must never re-prefill");
    }

    #[test]
    fn mid_prefill_detach_resumes_at_cursor() {
        let policy = BatchPolicy { chunk_tokens: 4, token_budget: 8, ..BatchPolicy::default() };
        let solo = {
            let mut s = Scheduler::new(MockEngine::new(), policy.clone());
            let prompt: Vec<i32> = (0..24).map(|x| x % 17).collect();
            s.submit(Request { id: 9, prompt, max_new_tokens: 3 }).unwrap();
            s.run_until_drained().unwrap().remove(0).tokens
        };
        let mut a = Scheduler::new(MockEngine::new(), policy.clone());
        let mut b = Scheduler::new(MockEngine::new(), policy);
        b.set_shard(1);
        let prompt: Vec<i32> = (0..24).map(|x| x % 17).collect();
        a.submit(Request { id: 9, prompt, max_new_tokens: 3 }).unwrap();
        a.tick().unwrap();
        a.tick().unwrap();
        assert_eq!(a.waiting(), 1, "still mid-prefill");
        let p = a.detach(9).expect("mid-prefill seq with state detaches");
        assert!(!p.decode_phase());
        assert_eq!(p.flight.prefill_pos, 8);
        b.attach(p).unwrap();
        let out = b.run_until_drained().unwrap();
        assert_eq!(out[0].tokens, solo);
        // Target only prefilled the *remaining* 16 tokens.
        assert_eq!(b.metrics().prefill_tokens, 16);
        assert_eq!(b.metrics().reprefills_avoided, 0, "partial move avoids no whole-history replay");
    }

    #[test]
    fn detach_refuses_pre_state_and_unknown_sequences() {
        let mut s = sched();
        s.submit(Request { id: 1, prompt: vec![2; 6], max_new_tokens: 2 }).unwrap();
        // No chunk has run: no resident state to move.
        assert!(s.detach(1).is_none());
        assert!(s.detach(42).is_none());
        // The request is untouched and still completes.
        assert_eq!(s.run_until_drained().unwrap().len(), 1);
    }

    #[test]
    fn reprefill_attach_matches_state_move_with_counted_replay() {
        let run = |reprefill: bool| {
            let mut a = sched();
            let mut b = sched();
            b.set_shard(1);
            a.submit(Request { id: 7, prompt: vec![5, 6, 7], max_new_tokens: 10 }).unwrap();
            for _ in 0..6 {
                a.tick().unwrap();
            }
            let p = a.detach(7).expect("running");
            let replay_cost = p.reprefill_cost_tokens();
            if reprefill {
                b.attach_reprefill(p);
            } else {
                b.attach(p).unwrap();
            }
            let out = b.run_until_drained().unwrap();
            (out[0].tokens.clone(), b.metrics().reprefill_tokens, replay_cost)
        };
        let (moved, moved_replay, _) = run(false);
        let (replayed, replay_counter, replay_cost) = run(true);
        assert_eq!(moved, replayed, "reprefill baseline must be token-identical");
        assert_eq!(moved_replay, 0);
        assert_eq!(replay_counter, replay_cost as u64);
        assert!(replay_counter > 0);
    }

    #[test]
    fn global_resident_bytes_sums_arena_and_remote() {
        let mut s = sched();
        assert_eq!(s.global_resident_bytes(), 0);
        s.set_remote_resident_bytes(4096);
        assert_eq!(s.global_resident_bytes(), 4096);
        s.submit(Request { id: 1, prompt: vec![1, 2], max_new_tokens: 4 }).unwrap();
        s.tick().unwrap();
        let own = s.state_arena().resident_bytes();
        assert!(own > 0);
        assert_eq!(s.global_resident_bytes(), own + 4096);
    }

    #[test]
    fn long_prompt_spans_many_ticks_before_first_token() {
        // chunk_tokens=4, token_budget=8: a 32-token prompt needs 8
        // chunk ticks before its first sampled token, and the prefill
        // cursor advances monotonically through them.
        let policy = BatchPolicy {
            chunk_tokens: 4,
            token_budget: 8,
            ..BatchPolicy::default()
        };
        let mut s = Scheduler::new(MockEngine::new(), policy);
        let prompt: Vec<i32> = (0..32).map(|x| x % 17).collect();
        s.submit(Request { id: 9, prompt, max_new_tokens: 2 }).unwrap();
        let mut prefill_ticks = 0;
        while s.metrics().requests_completed == 0 {
            let before = s.metrics().prefill_tokens;
            s.tick().unwrap();
            if s.metrics().prefill_tokens > before {
                prefill_ticks += 1;
            }
        }
        assert_eq!(prefill_ticks, 8);
        assert_eq!(s.metrics().prefill_tokens, 32);
        assert_eq!(s.metrics().max_tick_tokens, 4);
    }

    #[test]
    fn chunked_prefill_interleaves_with_decode() {
        // While a long prompt is mid-prefill, already-running sequences
        // keep decoding every tick — no full-tick stalls.
        let policy = BatchPolicy {
            chunk_tokens: 4,
            token_budget: 8,
            ..BatchPolicy::default()
        };
        let m = MockEngine::new();
        let vocab = m.manifest().vocab;
        let mut s = Scheduler::new(m, policy);
        // A short prompt that finishes prefill immediately and then
        // decodes for a long time.
        s.submit(Request { id: 1, prompt: vec![3, 1, 4], max_new_tokens: 40 }).unwrap();
        s.tick().unwrap(); // seq 1 prefills and starts running
        // Now a long prompt floods in.
        let prompt: Vec<i32> = (0..48).map(|x| x % vocab as i32).collect();
        s.submit(Request { id: 2, prompt, max_new_tokens: 1 }).unwrap();
        // Every subsequent tick must advance seq 1 by exactly one token
        // while seq 2's prefill progresses.
        for _ in 0..12 {
            let gen_before = s.metrics().tokens_generated;
            let pre_before = s.metrics().prefill_tokens;
            s.tick().unwrap();
            assert!(s.metrics().tokens_generated > gen_before, "decode stalled");
            if s.metrics().requests_completed == 0 {
                assert!(s.metrics().prefill_tokens > pre_before, "prefill stalled");
            }
        }
    }

    #[test]
    fn failed_tick_records_exactly_the_launched_rows_as_suspect() {
        use crate::runtime::fault::{FaultInjector, FaultPlan};
        // Tight budget: each tick decodes exactly one running row, so
        // the failing launch touches exactly one known sequence.
        let policy = BatchPolicy {
            chunk_tokens: 4,
            token_budget: 1,
            max_chunk_rows: 1,
            ..BatchPolicy::default()
        };
        let mut donor = sched();
        for id in 0..3u64 {
            donor
                .submit(Request { id, prompt: vec![3, 1, 4, 1], max_new_tokens: 10 })
                .unwrap();
        }
        for _ in 0..4 {
            donor.tick().unwrap();
        }
        assert_eq!(donor.running(), 3);
        let inj = FaultInjector::new(FaultPlan::Nth(2));
        let mut faulty = Scheduler::with_path(
            inj.wrap(MockEngine::new()).unwrap(),
            policy,
            StatePath::Resident,
        );
        for id in 0..3u64 {
            faulty.attach(donor.detach(id).unwrap()).unwrap();
        }
        faulty.tick().unwrap(); // decodes seq 0
        let err = faulty.tick().expect_err("second launch is planned to fail");
        assert!(format!("{err}").contains("injected launch fault"), "{err}");
        assert!(faulty.poisoned());
        assert_eq!(faulty.suspect_rows(), &[1], "round-robin reached seq 1");
        assert_eq!(inj.faults_injected(), 1);
        // Poisoned schedulers still refuse detach — salvage is the exit.
        assert!(faulty.detach(0).is_none());
    }

    #[test]
    fn salvage_resumes_untouched_rows_bit_identical_and_reprefills_suspects() {
        use crate::runtime::fault::{FaultInjector, FaultPlan};
        let reqs: Vec<Request> = (0..3u64)
            .map(|id| Request {
                id,
                prompt: vec![3, 1, 4, 1, 5],
                max_new_tokens: 9 + id as usize,
            })
            .collect();
        // Fault-free baseline.
        let baseline: Vec<Vec<i32>> = {
            let mut s = sched();
            for r in &reqs {
                s.submit(r.clone()).unwrap();
            }
            let mut out = s.run_until_drained().unwrap();
            out.sort_by_key(|r| r.id);
            out.into_iter().map(|r| r.tokens).collect()
        };

        // Build a decode-phase population, move it onto a faulty worker.
        let mut donor = sched();
        donor.set_shard(0);
        for r in &reqs {
            donor.submit(r.clone()).unwrap();
        }
        for _ in 0..4 {
            donor.tick().unwrap();
        }
        assert_eq!(donor.running(), 3);
        let inj = FaultInjector::new(FaultPlan::Nth(2));
        let tight = BatchPolicy {
            chunk_tokens: 4,
            token_budget: 1,
            max_chunk_rows: 1,
            ..BatchPolicy::default()
        };
        let mut faulty =
            Scheduler::with_path(inj.wrap(MockEngine::new()).unwrap(), tight, StatePath::Resident);
        faulty.set_shard(1);
        for r in &reqs {
            faulty.attach(donor.detach(r.id).unwrap()).unwrap();
        }
        faulty.tick().unwrap();
        faulty.tick().expect_err("planned fault");

        // Salvage: suspect seq 1 becomes token-only, 0 and 2 carry state.
        let packets = faulty.salvage();
        assert_eq!(packets.len(), 3);
        assert_eq!(
            packets.iter().map(|p| p.seq()).collect::<Vec<_>>(),
            vec![0, 1, 2],
            "ascending sequence order"
        );
        let mut healthy = sched();
        healthy.set_shard(2);
        let (mut carried, mut replayed_rows) = (0, 0);
        for p in packets {
            if p.state_bytes() > 0 {
                carried += 1;
                healthy.attach(p).expect("state-carrying salvage packet attaches");
            } else {
                replayed_rows += 1;
                assert_eq!(p.seq(), 1, "only the suspect row lost its state");
                let rejected = healthy.attach(p).expect_err("token-only packet must not attach");
                healthy.attach_reprefill(rejected);
            }
        }
        assert_eq!((carried, replayed_rows), (2, 1));
        let mut out = healthy.run_until_drained().unwrap();
        out.sort_by_key(|r| r.id);
        let tokens: Vec<Vec<i32>> = out.into_iter().map(|r| r.tokens).collect();
        assert_eq!(tokens, baseline, "salvaged serving must be bit-identical");
        // Conservation: two counted state copies in, one replay.
        assert_eq!(healthy.metrics().migrations, 3);
        assert_eq!(
            healthy.metrics().bytes_migrated,
            2 * healthy.state_arena().bytes_per_seq() as u64
        );
        assert!(healthy.metrics().reprefill_tokens > 0);
    }

    #[test]
    fn salvage_of_unstarted_rows_is_a_free_resubmit() {
        use crate::runtime::fault::{FaultInjector, FaultPlan};
        // Queue two prompts behind a tiny chunk budget and fail the
        // very first launch: the head row is suspect (it was in the
        // failing chunk), the second never started — its salvage packet
        // must replay zero tokens.
        let policy = BatchPolicy {
            chunk_tokens: 2,
            token_budget: 2,
            max_chunk_rows: 1,
            ..BatchPolicy::default()
        };
        let inj = FaultInjector::new(FaultPlan::Nth(1));
        let mut faulty =
            Scheduler::with_path(inj.wrap(MockEngine::new()).unwrap(), policy, StatePath::Resident);
        faulty
            .submit(Request { id: 7, prompt: vec![1, 2, 3, 4], max_new_tokens: 2 })
            .unwrap();
        faulty
            .submit(Request { id: 8, prompt: vec![5, 6], max_new_tokens: 2 })
            .unwrap();
        faulty.tick().expect_err("first launch fails");
        assert_eq!(faulty.suspect_rows(), &[7]);
        let packets = faulty.salvage();
        assert_eq!(packets.len(), 2);
        for p in &packets {
            assert_eq!(p.state_bytes(), 0, "no trusted state existed yet");
            assert_eq!(p.flight.prefill_pos, 0, "cursors never advanced");
            assert_eq!(p.reprefill_cost_tokens(), 0, "resubmission is free");
        }
    }

    #[test]
    fn trace_reconciles_with_traffic_counters() {
        use crate::obs;
        let mut s = sched();
        let m = s.manifest();
        let mut gen = WorkloadGen::new(23, m.vocab, m.prefill_len, 2, 7).with_prompt_range(1, 24);
        for _ in 0..6 {
            s.submit(gen.next_request()).unwrap();
        }
        s.run_until_drained().unwrap();
        assert_eq!(s.trace_dropped(), 0);
        let events = s.take_trace();
        let snap = s.metrics().traffic_snapshot();
        obs::reconcile(&events, &snap).unwrap();
        // Exactly one terminal event per submitted request, spans well
        // formed on the single shard.
        let spans = obs::assemble_spans(&events);
        assert_eq!(spans.len(), 6);
        for sp in &spans {
            assert_eq!(sp.terminal().map(|e| e.name()), Some("completed"));
            assert_eq!(sp.shards, vec![0]);
        }
        // Draining resets the ring; the next tick starts a fresh trace.
        assert!(s.take_trace().is_empty());
    }

    #[test]
    fn trace_covers_snapshot_hits_and_tick_latency() {
        use crate::obs::{self, TraceEvent};
        let mut s = sched();
        let prompt = vec![1, 2, 3, 4];
        s.submit_session(Request { id: 1, prompt: prompt.clone(), max_new_tokens: 3 }, Some(9))
            .unwrap();
        s.run_until_drained().unwrap();
        // Second turn extends the history recorded by the first —
        // snapshot hit skips the shared prefix.
        let mut p2 = prompt.clone();
        // Drain between turns: each trace window reconciles against
        // the counters accumulated so far (cumulative at this point ==
        // exactly turn one).
        let first = s.take_trace();
        obs::reconcile(&first, &s.metrics().traffic_snapshot()).unwrap();
        p2.extend([7, 8, 9]);
        s.submit_session(Request { id: 2, prompt: p2, max_new_tokens: 2 }, Some(9)).unwrap();
        s.run_until_drained().unwrap();
        let events = s.take_trace();
        let skipped: u64 = events
            .iter()
            .filter_map(|r| match r.event {
                TraceEvent::SnapshotHit { tokens_skipped } => Some(tokens_skipped),
                _ => None,
            })
            .sum();
        assert!(skipped > 0, "session reuse must emit a SnapshotHit event");
        assert_eq!(skipped, s.metrics().prefill_tokens_skipped);
        // Tick-denominated latency recorded deterministically.
        let lat = s.latency_report();
        assert_eq!(lat.ttft_ticks.count(), 2);
        assert_eq!(lat.total_ticks.count(), 2);
        assert!(lat.total_ticks.max() >= lat.ttft_ticks.max());
    }
}
