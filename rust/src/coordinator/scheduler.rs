//! The serving scheduler: drives prefill/decode batches over an
//! [`Executor`], carrying per-sequence recurrent state between steps.
//!
//! One `tick()` = one engine invocation (a prefill batch or a decode
//! step), chosen by the [`Batcher`] policy. Greedy (argmax) sampling.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::engine::{argmax_rows, Executor};

use super::batcher::{Action, Batcher, BatchPolicy};
use super::metrics::Metrics;
use super::request::{InFlight, Request, Response};
use super::state::StateManager;

/// Single-threaded scheduling core (wrapped by [`super::server::Server`]
/// for threaded serving).
pub struct Scheduler<E: Executor> {
    engine: E,
    batcher: Batcher,
    states: StateManager,
    /// Submitted, awaiting prefill.
    waiting: BTreeMap<u64, InFlight>,
    /// Prefilled, generating.
    running: BTreeMap<u64, InFlight>,
    metrics: Metrics,
}

impl<E: Executor> Scheduler<E> {
    pub fn new(engine: E, policy: BatchPolicy) -> Scheduler<E> {
        let m = engine.manifest();
        let states = StateManager::new(
            m.n_layer,
            m.d_inner * (m.d_conv - 1),
            m.d_inner * m.d_state,
        );
        Scheduler {
            engine,
            batcher: Batcher::new(policy),
            states,
            waiting: BTreeMap::new(),
            running: BTreeMap::new(),
            metrics: Metrics::new(),
        }
    }

    /// Accept a request (prompt must match the compiled prefill length).
    pub fn submit(&mut self, req: Request) -> Result<()> {
        let want = self.engine.manifest().prefill_len;
        anyhow::ensure!(
            req.prompt.len() == want,
            "prompt length {} != compiled prefill length {want}",
            req.prompt.len()
        );
        anyhow::ensure!(req.max_new_tokens >= 1, "must generate at least one token");
        self.batcher.enqueue(req.id);
        self.waiting.insert(req.id, InFlight::new(req));
        Ok(())
    }

    pub fn pending(&self) -> usize {
        self.waiting.len() + self.running.len()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn manifest(&self) -> &crate::runtime::artifact::Manifest {
        self.engine.manifest()
    }

    /// One scheduling step. Returns completed responses (possibly
    /// empty). `Ok(false)` means there was nothing to do.
    pub fn tick(&mut self) -> Result<(Vec<Response>, bool)> {
        let action = self.batcher.next_action(self.running.len(), Instant::now());
        match action {
            Action::Idle => Ok((Vec::new(), false)),
            Action::Prefill { admit, size } => {
                let ids = self.batcher.admit(admit);
                let done = self.do_prefill(&ids, size)?;
                Ok((done, true))
            }
            Action::Decode { size } => {
                let ids: Vec<u64> = self.running.keys().copied().take(size).collect();
                let done = self.do_decode(&ids, size)?;
                Ok((done, true))
            }
        }
    }

    /// Run until every submitted request completes; returns responses in
    /// completion order.
    pub fn run_until_drained(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        while self.pending() > 0 {
            let (done, progressed) = self.tick()?;
            out.extend(done);
            if !progressed && self.pending() > 0 {
                // Only reachable when requests wait on the age-out timer.
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
        }
        Ok(out)
    }

    fn vocab(&self) -> usize {
        self.engine.manifest().vocab
    }

    fn do_prefill(&mut self, ids: &[u64], size: usize) -> Result<Vec<Response>> {
        assert!(!ids.is_empty() && ids.len() <= size);
        let plen = self.engine.manifest().prefill_len;
        let mut tokens = Vec::with_capacity(size * plen);
        for b in 0..size {
            let id = ids[b.min(ids.len() - 1)]; // pad by repeating last
            tokens.extend_from_slice(&self.waiting[&id].req.prompt);
        }
        let out = self.engine.prefill(size, &tokens)?;
        self.metrics.record_prefill(ids.len(), ids.len() * plen);
        let next = argmax_rows(&out.logits, self.vocab());
        let now = Instant::now();
        let mut completed = Vec::new();
        for (b, &id) in ids.iter().enumerate() {
            let mut fl = self.waiting.remove(&id).expect("waiting entry");
            fl.first_token = Some(now);
            fl.generated.push(next[b]);
            self.metrics.record_decode(1, 1); // the prefill-produced token
            if fl.done() {
                completed.push(fl.finish());
                self.metrics
                    .record_completion(completed.last().unwrap().ttft, completed.last().unwrap().total);
            } else {
                self.states.install_from_batch(id, size, b, &out.conv_state, &out.ssm_state);
                self.running.insert(id, fl);
            }
        }
        Ok(completed)
    }

    fn do_decode(&mut self, ids: &[u64], size: usize) -> Result<Vec<Response>> {
        assert!(!ids.is_empty() && ids.len() <= size);
        let tokens: Vec<i32> = (0..size)
            .map(|b| {
                let id = ids[b.min(ids.len() - 1)];
                *self.running[&id].generated.last().expect("running seq has a token")
            })
            .collect();
        let (conv, ssm) = self.states.gather(ids, size);
        let out = self.engine.decode(size, &tokens, &conv, &ssm)?;
        self.metrics.record_decode(ids.len(), size);
        let next = argmax_rows(&out.logits, self.vocab());
        self.states.scatter(ids, size, &out.conv_state, &out.ssm_state);
        let mut completed = Vec::new();
        for (b, &id) in ids.iter().enumerate() {
            let fl = self.running.get_mut(&id).expect("running entry");
            fl.generated.push(next[b]);
            if fl.done() {
                let fl = self.running.remove(&id).unwrap();
                self.states.release(id);
                let resp = fl.finish();
                self.metrics.record_completion(resp.ttft, resp.total);
                completed.push(resp);
            }
        }
        Ok(completed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::WorkloadGen;
    use crate::runtime::mock::MockEngine;

    fn sched() -> Scheduler<MockEngine> {
        Scheduler::new(MockEngine::new(), BatchPolicy::default())
    }

    #[test]
    fn single_request_completes() {
        let mut s = sched();
        let m = s.manifest();
        let (vocab, plen) = (m.vocab, m.prefill_len);
        let mut gen = WorkloadGen::new(1, vocab, plen, 3, 3);
        s.submit(gen.next_request()).unwrap();
        let out = s.run_until_drained().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens.len(), 3);
        assert!(out[0].total >= out[0].ttft);
        assert_eq!(s.metrics().requests_completed, 1);
    }

    #[test]
    fn batched_equals_solo_generation() {
        // The same request must generate the same tokens whether served
        // alone or dynamically batched with others — state gather/
        // scatter and padding must not leak across sequences.
        let m = MockEngine::new();
        let (vocab, plen) = (m.manifest().vocab, m.manifest().prefill_len);
        let mut gen = WorkloadGen::new(42, vocab, plen, 4, 4);
        let reqs: Vec<_> = (0..5).map(|_| gen.next_request()).collect();

        // Solo runs.
        let mut solo_tokens = Vec::new();
        for r in &reqs {
            let mut s = sched();
            s.submit(r.clone()).unwrap();
            let out = s.run_until_drained().unwrap();
            solo_tokens.push(out[0].tokens.clone());
        }

        // Batched run.
        let mut s = sched();
        for r in &reqs {
            s.submit(r.clone()).unwrap();
        }
        let mut out = s.run_until_drained().unwrap();
        out.sort_by_key(|r| r.id);
        for (resp, solo) in out.iter().zip(&solo_tokens) {
            assert_eq!(&resp.tokens, solo, "request {} diverged under batching", resp.id);
        }
    }

    #[test]
    fn staggered_submission_with_varied_lengths() {
        let mut s = sched();
        let m = s.manifest();
        let (vocab, plen) = (m.vocab, m.prefill_len);
        let mut gen = WorkloadGen::new(7, vocab, plen, 1, 9);
        let mut expected = 0usize;
        let mut responses = Vec::new();
        for wave in 0..4 {
            for _ in 0..=wave {
                let r = gen.next_request();
                expected += 1;
                s.submit(r).unwrap();
            }
            // Interleave some ticks between waves.
            for _ in 0..3 {
                let (done, _) = s.tick().unwrap();
                responses.extend(done);
            }
        }
        responses.extend(s.run_until_drained().unwrap());
        assert_eq!(responses.len(), expected);
        for r in &responses {
            assert!(!r.tokens.is_empty());
        }
        // All state slots were released.
        assert_eq!(s.pending(), 0);
    }

    #[test]
    fn rejects_bad_prompt_length() {
        let mut s = sched();
        let bad = Request { id: 1, prompt: vec![0; 3], max_new_tokens: 1 };
        assert!(s.submit(bad).is_err());
    }

    #[test]
    fn metrics_track_tokens() {
        let mut s = sched();
        let m = s.manifest();
        let mut gen = WorkloadGen::new(3, m.vocab, m.prefill_len, 5, 5);
        for _ in 0..3 {
            s.submit(gen.next_request()).unwrap();
        }
        s.run_until_drained().unwrap();
        assert_eq!(s.metrics().tokens_generated, 15);
        assert!(s.metrics().mean_occupancy() > 0.0);
    }
}
