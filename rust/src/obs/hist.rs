//! Log2-bucketed mergeable latency histograms.
//!
//! The serving stack needs percentiles in two places with two very
//! different requirements:
//!
//! * **per-worker reporting** on the scheduler hot path — recording a
//!   sample must be O(1) and allocation-free (the old `Vec<f64>` in
//!   `Metrics` grew without bound and `ttft_pct` cloned + sorted it on
//!   every query), and
//! * **server-wide aggregation** — per-worker percentiles cannot be
//!   averaged; the only way to get a true fleet p99 is to merge the
//!   underlying distributions. Log2 buckets merge by summing counts,
//!   so [`Histogram::merge`] makes cross-shard percentiles *exact at
//!   bucket resolution* (the merged histogram is bit-identical to the
//!   histogram of the pooled samples — see the unit suite).
//!
//! Values are unsigned integers in whatever unit the caller picks.
//! `Metrics` keeps two parallel families: **tick units** (the
//! deterministic scheduler clock — same workload, same numbers, every
//! run; these are what CI gates and `BENCH_trajectory.json` record)
//! and **wall microseconds** (reporting only, never gated).
//!
//! ## Bucket semantics
//!
//! Bucket 0 holds exactly the value 0; bucket `b >= 1` holds the range
//! `[2^(b-1), 2^b - 1]`. [`Histogram::percentile`] walks the
//! cumulative counts to the target rank and returns that bucket's
//! upper bound clamped into `[min, max]` — i.e. an upper estimate
//! within one log2 bucket width of the exact order statistic, never
//! below `min`, and exact at the top (p→1 reports `max`).

/// Number of log2 buckets. Bucket 31 is open-ended (values ≥ 2^30
/// saturate into it); tick- and microsecond-denominated latencies in
/// this stack sit far below that.
pub const HIST_BUCKETS: usize = 32;

/// A fixed-size, `Copy`, mergeable log2 histogram.
///
/// `Copy` is load-bearing: histograms ride in query replies over the
/// worker channels (`Server::latency`) and live inline in `Metrics`
/// with zero heap footprint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Histogram {
    counts: [u64; HIST_BUCKETS],
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// An empty histogram.
    pub const fn new() -> Histogram {
        Histogram { counts: [0; HIST_BUCKETS], count: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    /// Bucket index for a value: 0 → 0, else `64 - leading_zeros`
    /// clamped, so bucket `b >= 1` spans `[2^(b-1), 2^b - 1]`.
    fn bucket_of(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            ((64 - v.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
        }
    }

    /// Inclusive upper bound of bucket `b` (the percentile estimate
    /// reported for ranks landing in `b`).
    pub fn bucket_upper(b: usize) -> u64 {
        if b == 0 {
            0
        } else {
            (1u64 << b.min(63)) - 1
        }
    }

    /// Record one sample. O(1), allocation-free.
    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Record a wall-clock duration in seconds as whole microseconds.
    pub fn record_secs(&mut self, secs: f64) {
        self.record((secs.max(0.0) * 1e6).round() as u64);
    }

    /// Fold `other` into `self`. Bucket counts sum, so the merged
    /// percentiles equal the pooled-samples percentiles exactly at
    /// bucket resolution — this is what makes server-wide p50/p99
    /// across shards trustworthy.
    pub fn merge(&mut self, other: &Histogram) {
        for (c, o) in self.counts.iter_mut().zip(other.counts.iter()) {
            *c += o;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        if other.count > 0 {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of recorded samples (saturating).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Smallest recorded sample (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Largest recorded sample (0 when empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Mean of recorded samples (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Percentile estimate for `p` in `[0, 1]`: the upper bound of the
    /// bucket holding the rank-`ceil(p·count)` sample, clamped into
    /// `[min, max]`. Returns 0 on an empty histogram. Monotone in `p`.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((p.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (b, c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return Self::bucket_upper(b).min(self.max).max(self.min);
            }
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    #[test]
    fn bucket_ranges() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 1);
        assert_eq!(Histogram::bucket_of(2), 2);
        assert_eq!(Histogram::bucket_of(3), 2);
        assert_eq!(Histogram::bucket_of(4), 3);
        assert_eq!(Histogram::bucket_of(7), 3);
        assert_eq!(Histogram::bucket_of(8), 4);
        assert_eq!(Histogram::bucket_of(u64::MAX), HIST_BUCKETS - 1);
        for b in 1..HIST_BUCKETS - 1 {
            // b's range is [2^(b-1), 2^b - 1] and upper() is its top.
            assert_eq!(Histogram::bucket_of(1 << (b - 1)), b);
            assert_eq!(Histogram::bucket_of(Histogram::bucket_upper(b)), b);
        }
    }

    #[test]
    fn empty_and_single() {
        let h = Histogram::new();
        assert_eq!(h.percentile(0.5), 0);
        assert_eq!(h.count(), 0);
        assert_eq!(h.min(), 0);
        let mut h = Histogram::new();
        h.record(37);
        assert_eq!(h.percentile(0.0), 37);
        assert_eq!(h.percentile(1.0), 37);
        assert_eq!(h.mean(), 37.0);
    }

    /// The tentpole property: merging per-shard histograms gives the
    /// same percentiles as pooling every sample and sorting, within
    /// one log2 bucket width — and exactly at the extremes.
    #[test]
    fn merge_matches_pooled_sort_within_one_bucket() {
        let mut rng = XorShift::new(0x0b5);
        for _ in 0..50 {
            let mut merged = Histogram::new();
            let mut pooled: Vec<u64> = Vec::new();
            for _ in 0..4 {
                let n = rng.below(60) as usize;
                let mut shard = Histogram::new();
                for _ in 0..n {
                    let v = rng.below(5000);
                    shard.record(v);
                    pooled.push(v);
                }
                merged.merge(&shard);
            }
            pooled.sort_unstable();
            assert_eq!(merged.count() as usize, pooled.len());
            let mut last = 0u64;
            for &p in &[0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
                let got = merged.percentile(p);
                assert!(got >= last, "percentile not monotone at p={p}");
                last = got;
                if pooled.is_empty() {
                    assert_eq!(got, 0);
                    continue;
                }
                let rank = ((p * pooled.len() as f64).ceil() as usize).clamp(1, pooled.len());
                let exact = pooled[rank - 1];
                // Upper estimate, within one bucket width of exact.
                assert!(
                    got >= exact || got == merged.max(),
                    "p={p}: got {got} < exact {exact}"
                );
                assert!(got <= 2 * exact + 1, "p={p}: got {got} > 2*{exact}+1");
            }
            assert_eq!(merged.percentile(1.0), *pooled.last().unwrap_or(&0));
        }
    }

    /// Merge equals recording the pooled samples directly — the
    /// bit-for-bit form of aggregation exactness.
    #[test]
    fn merge_is_bit_identical_to_pooled_recording() {
        let mut rng = XorShift::new(9);
        let a: Vec<u64> = (0..40).map(|_| rng.below(1 << 20)).collect();
        let b: Vec<u64> = (0..25).map(|_| rng.below(1 << 20)).collect();
        let (mut ha, mut hb, mut pooled) = (Histogram::new(), Histogram::new(), Histogram::new());
        for &v in &a {
            ha.record(v);
            pooled.record(v);
        }
        for &v in &b {
            hb.record(v);
            pooled.record(v);
        }
        ha.merge(&hb);
        assert_eq!(ha, pooled);
    }
}
