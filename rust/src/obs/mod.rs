//! Observability: deterministic request-lifecycle tracing and
//! mergeable latency histograms.
//!
//! The serving stack's perf argument is a *traffic-accounting*
//! argument — every byte, device call and migration is a deterministic
//! counter. This layer makes those aggregates attributable:
//!
//! * [`trace`] — typed [`TraceEvent`]s stamped with the scheduler's
//!   tick clock, recorded into a bounded pre-allocated [`TraceRing`]
//!   per worker, stitched into per-request [`Span`]s across
//!   migration/salvage hops, exported as Perfetto-viewable Chrome
//!   trace JSON, and [`reconcile`]d bit-for-bit against the
//!   independent traffic counters so the trace can never silently
//!   drift from the numbers CI gates on.
//! * [`hist`] — log2-bucketed `Copy` [`Histogram`]s whose `merge()`
//!   makes cross-shard latency percentiles exact at bucket
//!   resolution, in deterministic tick units (gateable) and wall
//!   microseconds (reporting).
//!
//! Nothing here allocates on the steady-state decode path: ring slots
//! and histogram buckets are fixed-size and `Copy`, and overflow is a
//! counted event ([`TraceRing::events_dropped`]), not an allocation
//! or a silent loss.

pub mod hist;
pub mod trace;

pub use hist::{Histogram, HIST_BUCKETS};
pub use trace::{
    assemble_spans, chrome_trace, reconcile, Span, TraceEvent, TraceRecord, TraceRing,
    DEFAULT_TRACE_CAP, WORKER_SEQ,
};
