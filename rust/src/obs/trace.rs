//! Deterministic request-lifecycle tracing.
//!
//! Every hop a request takes through the serving stack — admission,
//! routing, chunked prefill, engine launches, first token, snapshot
//! hits, migration, faults, salvage, terminal completion or failure —
//! is recorded as a typed [`TraceEvent`] stamped with the scheduler's
//! **deterministic tick clock** (never wall time: same workload, same
//! trace, every run). Events land in a bounded, pre-allocated
//! [`TraceRing`] per worker, so steady-state decode ticks stay
//! zero-allocation with tracing enabled; overflow is *counted*
//! ([`TraceRing::events_dropped`]), never silent.
//!
//! Tracing here is **trustworthy rather than decorative** because of
//! [`reconcile`]: summed trace events must equal the independently
//! maintained traffic counters exactly (Σ `Launch.device_calls` ==
//! `device_calls`, migration events == `migrations`, snapshot hits,
//! and exactly one terminal event per submitted request — the
//! supervision sink contract, now observable). Every bench gate runs
//! this check, so trace drift fails CI immediately.
//!
//! [`chrome_trace`] exports a drained event set as Chrome trace-event
//! JSON viewable in Perfetto (`serve_mamba --trace-out trace.json`):
//! one track per shard for worker-scoped launches, one track per
//! request for its lifecycle span.

use std::collections::BTreeMap;

use crate::coordinator::TrafficSnapshot;
use crate::util::JsonValue;

/// Sentinel `seq` for worker-scoped records (per-tick launches,
/// faults) that belong to a shard's track rather than any request.
pub const WORKER_SEQ: u64 = u64::MAX;

/// Default per-worker ring capacity. Sized so every gated scenario
/// drains with zero drops (reconciliation requires the full event
/// stream); at 32 bytes per slot this is 256 KiB per worker.
pub const DEFAULT_TRACE_CAP: usize = 8192;

/// One step of a request's lifecycle (or a worker-scoped engine
/// event). Payloads are `Copy` only — no strings, no heap — so
/// recording never allocates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceEvent {
    /// Request entered a scheduler's waiting queue.
    Submit,
    /// Router placed the request on `shard` (server-side, pre-submit).
    Routed {
        /// Destination shard index.
        shard: u32,
    },
    /// A session follow-up attached a cached snapshot row and skipped
    /// re-prefilling `tokens_skipped` history tokens.
    SnapshotHit {
        /// Prompt tokens the cache made unnecessary.
        tokens_skipped: u64,
    },
    /// A prefill chunk of `chunk_len` tokens was batched into this
    /// tick, starting at prompt offset `cursor`.
    ChunkScheduled {
        /// Tokens in this chunk row.
        chunk_len: u32,
        /// Prompt offset the chunk starts at.
        cursor: u32,
    },
    /// One mixed engine launch (worker-scoped, `seq == WORKER_SEQ`).
    /// `staged_bytes` is the tick's gather+scatter traffic drained
    /// from the engine workspace — zero on the resident fast path.
    Launch {
        /// Fusion plan index (`PlanChoice::index()`) the tick ran under.
        plan: u8,
        /// Device calls the launch decomposed into.
        device_calls: u64,
        /// Gathered + scattered state bytes staged for this tick.
        staged_bytes: u64,
    },
    /// The request emitted its first generated token.
    FirstToken,
    /// The request's resident state row left this worker (planned
    /// migration detach); `shard` is the row's home shard.
    MigrationOut {
        /// Shard the row detached from.
        shard: u32,
    },
    /// The request attached on this worker; `shard` is where its
    /// state (or replay history) came from.
    MigrationIn {
        /// Source shard of the attached packet.
        shard: u32,
    },
    /// A re-prefill attach replayed `tokens` prompt+history tokens.
    Replayed {
        /// Tokens replayed through prefill.
        tokens: u64,
    },
    /// An engine launch failed and poisoned this worker
    /// (worker-scoped, `seq == WORKER_SEQ`).
    Fault,
    /// The request was exported from a poisoned worker's salvage;
    /// `state_carrying` says whether its state rows travelled with it
    /// (vs. a token-only packet that must re-prefill).
    Salvaged {
        /// True when the packet carries resident state rows.
        state_carrying: bool,
    },
    /// Terminal: the request completed and its sink got the response.
    Completed,
    /// Terminal: the request failed and its sink got an error
    /// response (retry budget exhausted, no healthy worker, …).
    Failed,
}

impl TraceEvent {
    /// Short stable name (the Chrome-trace event name).
    pub fn name(&self) -> &'static str {
        match self {
            TraceEvent::Submit => "submit",
            TraceEvent::Routed { .. } => "routed",
            TraceEvent::SnapshotHit { .. } => "snapshot_hit",
            TraceEvent::ChunkScheduled { .. } => "chunk_scheduled",
            TraceEvent::Launch { .. } => "launch",
            TraceEvent::FirstToken => "first_token",
            TraceEvent::MigrationOut { .. } => "migration_out",
            TraceEvent::MigrationIn { .. } => "migration_in",
            TraceEvent::Replayed { .. } => "replayed",
            TraceEvent::Fault => "fault",
            TraceEvent::Salvaged { .. } => "salvaged",
            TraceEvent::Completed => "completed",
            TraceEvent::Failed => "failed",
        }
    }

    /// True for the two span-ending events. Every submitted request
    /// must produce exactly one ([`reconcile`] enforces it).
    pub fn is_terminal(&self) -> bool {
        matches!(self, TraceEvent::Completed | TraceEvent::Failed)
    }
}

/// One ring slot: which request (`seq`), when (deterministic `tick`
/// of the recording worker's clock), where (`shard`), what (`event`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceRecord {
    /// Request id, or [`WORKER_SEQ`] for worker-scoped events.
    pub seq: u64,
    /// The recording scheduler's tick count at the event (0 for
    /// server-side router events — the router has no tick clock).
    pub tick: u64,
    /// Shard the event was recorded on (or routed to).
    pub shard: u32,
    /// The lifecycle step.
    pub event: TraceEvent,
}

impl Default for TraceRecord {
    fn default() -> Self {
        TraceRecord { seq: WORKER_SEQ, tick: 0, shard: 0, event: TraceEvent::Submit }
    }
}

/// Bounded per-worker event ring. All slots are allocated up front;
/// when full, a push overwrites the **oldest** record and bumps
/// `events_dropped` — the hot path never allocates and never blocks,
/// and loss is observable instead of silent.
#[derive(Debug)]
pub struct TraceRing {
    slots: Vec<TraceRecord>,
    head: usize,
    len: usize,
    dropped: u64,
}

impl TraceRing {
    /// A ring with `cap` pre-allocated slots (min 1).
    pub fn new(cap: usize) -> TraceRing {
        TraceRing { slots: vec![TraceRecord::default(); cap.max(1)], head: 0, len: 0, dropped: 0 }
    }

    /// Record an event. O(1), allocation-free; overwrites the oldest
    /// record (counting it dropped) when full.
    pub fn push(&mut self, rec: TraceRecord) {
        let cap = self.slots.len();
        if self.len < cap {
            self.slots[(self.head + self.len) % cap] = rec;
            self.len += 1;
        } else {
            self.slots[self.head] = rec;
            self.head = (self.head + 1) % cap;
            self.dropped += 1;
        }
    }

    /// Records currently buffered.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when nothing is buffered.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Slot capacity.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Cumulative count of records lost to overwrite. Non-zero means
    /// the drained stream is incomplete and [`reconcile`] against it
    /// is not meaningful — size the ring up or drain more often.
    pub fn events_dropped(&self) -> u64 {
        self.dropped
    }

    /// Append all buffered records (oldest first) to `out` and reset
    /// the ring. The drop counter is cumulative and survives drains.
    pub fn drain_into(&mut self, out: &mut Vec<TraceRecord>) {
        let cap = self.slots.len();
        out.reserve(self.len);
        for i in 0..self.len {
            out.push(self.slots[(self.head + i) % cap]);
        }
        self.head = 0;
        self.len = 0;
    }
}

/// A stitched per-request span: every event recorded for one `seq`,
/// in drain order, across however many shards the request visited
/// (migration and salvage make multi-shard spans).
#[derive(Debug, Clone)]
pub struct Span {
    /// The request id.
    pub seq: u64,
    /// Tick of the first event (on its recording worker's clock).
    pub start_tick: u64,
    /// Tick of the last event (on its recording worker's clock).
    pub end_tick: u64,
    /// Shards visited, consecutive duplicates collapsed, in order.
    pub shards: Vec<u32>,
    /// The span's events in recorded order.
    pub events: Vec<TraceRecord>,
}

impl Span {
    /// The span's terminal event, if it has ended.
    pub fn terminal(&self) -> Option<TraceEvent> {
        self.events.iter().rev().map(|r| r.event).find(TraceEvent::is_terminal)
    }
}

/// Group drained records into per-request [`Span`]s (worker-scoped
/// records are skipped), ordered by `seq`. Records for one request
/// must already be in causal order per worker; cross-worker stitching
/// relies on drain order (router first, then shard by shard), which
/// is how [`Server::trace`] assembles its stream.
///
/// [`Server::trace`]: crate::coordinator::Server::trace
pub fn assemble_spans(events: &[TraceRecord]) -> Vec<Span> {
    let mut by_seq: BTreeMap<u64, Vec<TraceRecord>> = BTreeMap::new();
    for &r in events {
        if r.seq != WORKER_SEQ {
            by_seq.entry(r.seq).or_default().push(r);
        }
    }
    by_seq
        .into_iter()
        .map(|(seq, events)| {
            let mut shards: Vec<u32> = Vec::new();
            for r in &events {
                if shards.last() != Some(&r.shard) {
                    shards.push(r.shard);
                }
            }
            Span {
                seq,
                start_tick: events.first().map_or(0, |r| r.tick),
                end_tick: events.last().map_or(0, |r| r.tick),
                shards,
                events,
            }
        })
        .collect()
}

/// Cross-check a drained event stream against the independently
/// maintained traffic counters. Passing means the trace is a faithful
/// account of what the counters measured:
///
/// * Σ `Launch.device_calls` == `snap.device_calls`
/// * Σ `Launch.staged_bytes` == `snap.bytes_gathered + bytes_scattered`
/// * `MigrationIn` count == `snap.migrations` (every counted attach —
///   planned move, salvage, or re-prefill — left an event)
/// * `SnapshotHit` count == `snap.snapshot_hits`, and the skipped
///   tokens sum to `snap.prefill_tokens_skipped`
/// * Σ `Replayed.tokens` == `snap.reprefill_tokens`
/// * `Completed` count == `snap.requests_completed`
/// * every span with a `Submit` or `Routed` event has **exactly one**
///   terminal event; no span has more than one.
///
/// Returns every mismatch found (empty error list == `Ok`). The check
/// is only meaningful over a complete stream — drain with zero
/// [`TraceRing::events_dropped`].
pub fn reconcile(events: &[TraceRecord], snap: &TrafficSnapshot) -> Result<(), String> {
    let mut errs: Vec<String> = Vec::new();
    let mut check = |name: &str, got: u64, want: u64| {
        if got != want {
            errs.push(format!("{name}: trace says {got}, counters say {want}"));
        }
    };

    let (mut device_calls, mut staged, mut migr_in) = (0u64, 0u64, 0u64);
    let (mut snap_hits, mut skipped, mut replayed, mut completed) = (0u64, 0u64, 0u64, 0u64);
    for r in events {
        match r.event {
            TraceEvent::Launch { device_calls: d, staged_bytes: b, .. } => {
                device_calls += d;
                staged += b;
            }
            TraceEvent::MigrationIn { .. } => migr_in += 1,
            TraceEvent::SnapshotHit { tokens_skipped } => {
                snap_hits += 1;
                skipped += tokens_skipped;
            }
            TraceEvent::Replayed { tokens } => replayed += tokens,
            TraceEvent::Completed => completed += 1,
            _ => {}
        }
    }
    check("launch.device_calls", device_calls, snap.device_calls);
    check("launch.staged_bytes", staged, snap.bytes_gathered + snap.bytes_scattered);
    check("migration_in", migr_in, snap.migrations);
    check("snapshot_hit", snap_hits, snap.snapshot_hits);
    check("snapshot_hit.tokens_skipped", skipped, snap.prefill_tokens_skipped);
    check("replayed.tokens", replayed, snap.reprefill_tokens);
    check("completed", completed, snap.requests_completed);

    for span in assemble_spans(events) {
        let submitted = span
            .events
            .iter()
            .any(|r| matches!(r.event, TraceEvent::Submit | TraceEvent::Routed { .. }));
        let terminals = span.events.iter().filter(|r| r.event.is_terminal()).count();
        if submitted && terminals != 1 {
            errs.push(format!("seq {}: {} terminal events (want exactly 1)", span.seq, terminals));
        } else if terminals > 1 {
            errs.push(format!("seq {}: {} terminal events (want at most 1)", span.seq, terminals));
        }
    }

    if errs.is_empty() {
        Ok(())
    } else {
        Err(errs.join("; "))
    }
}

fn event_args(r: &TraceRecord) -> JsonValue {
    let mut args = JsonValue::obj();
    match r.event {
        TraceEvent::Routed { shard }
        | TraceEvent::MigrationOut { shard }
        | TraceEvent::MigrationIn { shard } => {
            args.set("shard", shard as u64);
        }
        TraceEvent::SnapshotHit { tokens_skipped } => {
            args.set("tokens_skipped", tokens_skipped);
        }
        TraceEvent::ChunkScheduled { chunk_len, cursor } => {
            args.set("chunk_len", chunk_len as u64).set("cursor", cursor as u64);
        }
        TraceEvent::Launch { plan, device_calls, staged_bytes } => {
            args.set("plan", plan as u64)
                .set("device_calls", device_calls)
                .set("staged_bytes", staged_bytes);
        }
        TraceEvent::Replayed { tokens } => {
            args.set("tokens", tokens);
        }
        TraceEvent::Salvaged { state_carrying } => {
            args.set("state_carrying", state_carrying);
        }
        _ => {}
    }
    args
}

/// Export a drained event stream as Chrome trace-event JSON (open the
/// file in Perfetto / `chrome://tracing`). Layout:
///
/// * **pid 1 "shards"** — one thread per shard; worker-scoped events
///   (`launch`, `fault`) as instants on their shard's track, `ts` =
///   the worker's deterministic tick.
/// * **pid 2 "requests"** — one thread per request; an `X` span from
///   first to last event plus an instant per lifecycle step. A
///   migrated or salvaged request's instants name the shards they
///   crossed (`args.shard`), which is how a hop reads in the UI.
///
/// Tick clocks are per-worker, so cross-track timestamps align only
/// loosely — the value of the export is ordering and attribution, not
/// cross-shard simultaneity.
pub fn chrome_trace(events: &[TraceRecord]) -> JsonValue {
    let mut out = Vec::new();
    let mut meta = |pid: u64, tid: u64, which: &str, name: String| {
        let mut m = JsonValue::obj();
        let mut args = JsonValue::obj();
        args.set("name", name);
        m.set("ph", "M").set("name", which).set("pid", pid).set("tid", tid).set("args", args);
        m
    };

    out.push(meta(1, 0, "process_name", "shards".to_string()));
    out.push(meta(2, 0, "process_name", "requests".to_string()));
    let mut shards_seen: Vec<u32> = events.iter().map(|r| r.shard).collect();
    shards_seen.sort_unstable();
    shards_seen.dedup();
    for s in shards_seen {
        out.push(meta(1, s as u64, "thread_name", format!("shard {s}")));
    }

    for r in events {
        let (pid, tid) = if r.seq == WORKER_SEQ { (1u64, r.shard as u64) } else { (2u64, r.seq) };
        let mut e = JsonValue::obj();
        e.set("name", r.event.name())
            .set("ph", "i")
            .set("s", "t")
            .set("ts", r.tick)
            .set("pid", pid)
            .set("tid", tid)
            .set("args", event_args(r));
        out.push(e);
    }

    for span in assemble_spans(events) {
        out.push(meta(2, span.seq, "thread_name", format!("req {}", span.seq)));
        let mut args = JsonValue::obj();
        let shards: Vec<JsonValue> =
            span.shards.iter().map(|&s| JsonValue::from(s as u64)).collect();
        args.set("shards", shards).set(
            "terminal",
            span.terminal().map_or("in_flight", |t| t.name()),
        );
        let mut e = JsonValue::obj();
        e.set("name", format!("req {}", span.seq))
            .set("ph", "X")
            .set("ts", span.start_tick)
            .set("dur", (span.end_tick.saturating_sub(span.start_tick)).max(1))
            .set("pid", 2u64)
            .set("tid", span.seq)
            .set("args", args);
        out.push(e);
    }

    let mut root = JsonValue::obj();
    root.set("displayTimeUnit", "ms").set("traceEvents", out);
    root
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(seq: u64, tick: u64, shard: u32, event: TraceEvent) -> TraceRecord {
        TraceRecord { seq, tick, shard, event }
    }

    /// Push 10 into a capacity-4 ring: the last 4 survive and exactly
    /// 6 are counted dropped — overflow is never silent.
    #[test]
    fn ring_wraparound_counts_drops_exactly() {
        let mut ring = TraceRing::new(4);
        for i in 0..10u64 {
            ring.push(rec(i, i, 0, TraceEvent::Submit));
        }
        assert_eq!(ring.len(), 4);
        assert_eq!(ring.events_dropped(), 6);
        let mut out = Vec::new();
        ring.drain_into(&mut out);
        assert_eq!(out.iter().map(|r| r.seq).collect::<Vec<_>>(), vec![6, 7, 8, 9]);
        assert!(ring.is_empty());
        // Drain resets contents but the drop counter is cumulative.
        assert_eq!(ring.events_dropped(), 6);
        for i in 0..3u64 {
            ring.push(rec(i, i, 0, TraceEvent::Submit));
        }
        let mut out = Vec::new();
        ring.drain_into(&mut out);
        assert_eq!(out.len(), 3);
        assert_eq!(ring.events_dropped(), 6);
    }

    #[test]
    fn ring_never_allocates_after_construction() {
        let mut ring = TraceRing::new(8);
        let base = ring.capacity();
        for i in 0..1000u64 {
            ring.push(rec(i, i, 0, TraceEvent::FirstToken));
        }
        assert_eq!(ring.capacity(), base);
        assert_eq!(ring.events_dropped(), 1000 - 8);
    }

    #[test]
    fn spans_stitch_across_shards() {
        let events = vec![
            rec(7, 0, 0, TraceEvent::Routed { shard: 0 }),
            rec(7, 1, 0, TraceEvent::Submit),
            rec(WORKER_SEQ, 2, 0, TraceEvent::Launch { plan: 0, device_calls: 3, staged_bytes: 0 }),
            rec(7, 4, 0, TraceEvent::MigrationOut { shard: 0 }),
            rec(7, 1, 1, TraceEvent::MigrationIn { shard: 0 }),
            rec(7, 3, 1, TraceEvent::Completed),
        ];
        let spans = assemble_spans(&events);
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].shards, vec![0, 1]);
        assert_eq!(spans[0].terminal(), Some(TraceEvent::Completed));
        assert_eq!(spans[0].events.len(), 5, "worker-scoped record excluded");
    }

    #[test]
    fn reconcile_catches_drift() {
        let mut snap = TrafficSnapshot::default();
        snap.device_calls = 3;
        snap.requests_completed = 1;
        let good = vec![
            rec(1, 0, 0, TraceEvent::Submit),
            rec(WORKER_SEQ, 1, 0, TraceEvent::Launch { plan: 0, device_calls: 3, staged_bytes: 0 }),
            rec(1, 2, 0, TraceEvent::Completed),
        ];
        assert!(reconcile(&good, &snap).is_ok());
        // Drift in a counter, a missing terminal, and a double
        // terminal are all caught.
        snap.device_calls = 4;
        assert!(reconcile(&good, &snap).unwrap_err().contains("device_calls"));
        snap.device_calls = 3;
        let unterminated = &good[..2];
        let err = reconcile(unterminated, &snap).unwrap_err();
        assert!(err.contains("terminal"), "{err}");
        let mut doubled = good.clone();
        doubled.push(rec(1, 3, 0, TraceEvent::Failed));
        assert!(reconcile(&doubled, &snap).unwrap_err().contains("terminal"));
    }

    #[test]
    fn chrome_export_is_valid_json_with_both_tracks() {
        let events = vec![
            rec(1, 0, 0, TraceEvent::Submit),
            rec(WORKER_SEQ, 1, 0, TraceEvent::Launch { plan: 2, device_calls: 1, staged_bytes: 64 }),
            rec(1, 1, 0, TraceEvent::FirstToken),
            rec(1, 2, 0, TraceEvent::Completed),
        ];
        let doc = chrome_trace(&events);
        let text = doc.to_string();
        let parsed = JsonValue::parse(&text).expect("exported trace must parse");
        let items = parsed.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        // 2 process metas + 1 shard meta + 4 instants + 1 req meta + 1 span.
        assert_eq!(items.len(), 9);
        assert!(text.contains("\"ph\":\"X\""));
        assert!(text.contains("\"first_token\""));
        assert!(text.contains("\"staged_bytes\":64"));
    }
}
