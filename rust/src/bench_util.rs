//! Mini benchmark harness (no criterion in the vendored crate set):
//! warmup + timed iterations with mean / p50 / p95 and a throughput
//! hook, plus the **shared serving-scenario builder** — the request
//! mixes and batch policies the hotpath bench, `serve_mamba` and the
//! planner gates all drive, defined once so the "bundled scenarios"
//! CI gates on are the same workloads everywhere.

use std::time::{Duration, Instant};

use crate::coordinator::{BatchPolicy, Request, WorkloadGen};

/// The request-mix shape of a [`ServeScenario`] (kept as data so the
/// mix can never desynchronize from the scenario name).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ScenarioMix {
    PrefillHeavy,
    DecodeHeavy,
    Interference,
    ShardedSkew,
    ChunkHeavy,
    MultiTurn,
    BestOfN,
    FaultStorm,
    Overload,
}

/// One scheduled arrival in the [`ServeScenario::overload`] storm: the
/// loop tick it enters the front door, its priority class, and the
/// request itself.
#[derive(Debug, Clone)]
pub struct OverloadArrival {
    /// Arrival tick on the submit-loop clock (same clock the admission
    /// window rolls on).
    pub tick: u64,
    /// [`crate::frontend::Priority`] index (0 = interactive, 2 = batch).
    pub class: usize,
    pub req: Request,
}

/// A named, deterministic serving workload: a batch policy plus a
/// request mix. The bundled set covers the paper's phase regimes —
/// prefill-heavy, decode-heavy, and the mixed long-prompt interference
/// scenario — so plan-selection quality is measured on the same axis
/// the paper sweeps (context:generation ratio).
#[derive(Debug, Clone)]
pub struct ServeScenario {
    pub name: &'static str,
    pub policy: BatchPolicy,
    mix: ScenarioMix,
}

impl ServeScenario {
    /// Prefill-dominated: four monolithic-chunk 4096-token prompts,
    /// one sampled token each — every tick is (almost) pure prefill at
    /// the paper's reference context length.
    pub fn prefill_heavy() -> ServeScenario {
        ServeScenario {
            name: "prefill_heavy",
            policy: BatchPolicy {
                chunk_tokens: 4096,
                token_budget: 4096,
                max_chunk_rows: 1,
                max_running: 8,
                decode_priority_threshold: 8,
            },
            mix: ScenarioMix::PrefillHeavy,
        }
    }

    /// Decode-dominated: eight 3-token prompts generating 48 tokens
    /// each — after two admission ticks, every tick is a batched
    /// decode step.
    pub fn decode_heavy() -> ServeScenario {
        ServeScenario {
            name: "decode_heavy",
            policy: BatchPolicy {
                chunk_tokens: 4,
                token_budget: 16,
                max_chunk_rows: 4,
                max_running: 8,
                decode_priority_threshold: 8,
            },
            mix: ScenarioMix::DecodeHeavy,
        }
    }

    /// Mixed interference: six short-prompt decoders ride along while
    /// one 512-token prompt prefills in chunks (the hotpath bench's
    /// long-standing scenario).
    pub fn interference() -> ServeScenario {
        ServeScenario {
            name: "interference",
            policy: BatchPolicy {
                chunk_tokens: 16,
                token_budget: 32,
                max_chunk_rows: 2,
                max_running: 8,
                decode_priority_threshold: 8,
            },
            mix: ScenarioMix::Interference,
        }
    }

    /// Hot-worker skew for the sharding gate: seven medium-prompt,
    /// long-generation requests. The bench pins ids 0..=5 to one
    /// worker (hot) and id 6 to another (cold), then migrates part of
    /// the hot decode set mid-flight — with state movement vs the
    /// re-prefill baseline vs no migration at all — and gates on the
    /// deterministic `bytes_migrated` / `reprefill_tokens` counters.
    pub fn sharded_skew() -> ServeScenario {
        ServeScenario {
            name: "sharded_skew",
            policy: BatchPolicy {
                chunk_tokens: 4,
                token_budget: 16,
                max_chunk_rows: 4,
                max_running: 8,
                decode_priority_threshold: 8,
            },
            mix: ScenarioMix::ShardedSkew,
        }
    }

    /// Request ids [`ServeScenario::sharded_skew`] pins to the hot
    /// worker (the rest go cold).
    pub const SHARDED_HOT_IDS: std::ops::Range<u64> = 0..6;

    /// Mid-prompt-chunk-dominated: four 42-token prompts split into
    /// 6-token chunks under a 12-token budget, short generations.
    /// Every chunk is exactly [`ServeScenario::CHUNK_HEAVY_LEN`] tokens
    /// and — deliberately — *not* the mock's compiled `prefill_len`,
    /// so every chunk row is a varlen scan row: exactly the kind the
    /// default engine decomposition pays `max(chunk)` lockstep device
    /// calls for and a fused varlen kernel serves in one launch. The
    /// `BENCH_engine_api.json` gate prices that gap on the
    /// deterministic `device_calls` / staged-bytes counters.
    pub fn chunk_heavy() -> ServeScenario {
        ServeScenario {
            name: "chunk_heavy",
            policy: BatchPolicy {
                chunk_tokens: Self::CHUNK_HEAVY_LEN,
                token_budget: 2 * Self::CHUNK_HEAVY_LEN,
                max_chunk_rows: 2,
                max_running: 8,
                decode_priority_threshold: 8,
            },
            mix: ScenarioMix::ChunkHeavy,
        }
    }

    /// Every [`ServeScenario::chunk_heavy`] chunk is exactly this many
    /// tokens (the prompt length is a multiple of it), so the
    /// decomposition's lockstep cost per chunk tick is exactly this
    /// many device calls.
    pub const CHUNK_HEAVY_LEN: usize = 6;

    /// Multi-turn chat for the snapshot gate: each of
    /// [`ServeScenario::MULTI_TURN_SESSIONS`] sessions opens with a
    /// 24-token prompt and an 8-token reply. The gate then builds each
    /// session's turn-2 prompt with [`ServeScenario::follow_up_prompt`]
    /// (turn-1 history plus [`ServeScenario::MULTI_TURN_NEW_TOKENS`]
    /// fresh tokens) and asserts turn 2 prefills *only* the new tokens
    /// — the history lands in `prefill_tokens_skipped`.
    pub fn multi_turn() -> ServeScenario {
        ServeScenario {
            name: "multi_turn",
            policy: BatchPolicy {
                chunk_tokens: 8,
                token_budget: 32,
                max_chunk_rows: 4,
                max_running: 8,
                decode_priority_threshold: 8,
            },
            mix: ScenarioMix::MultiTurn,
        }
    }

    /// Sessions in [`ServeScenario::multi_turn`].
    pub const MULTI_TURN_SESSIONS: u64 = 4;

    /// Fresh tokens each turn-2 prompt appends after its history.
    pub const MULTI_TURN_NEW_TOKENS: usize = 6;

    /// Best-of-N for the snapshot gate: one 32-token prompt generating
    /// a single token, whose session is then forked
    /// [`ServeScenario::BEST_OF_N`] ways — N decodes from exactly one
    /// prefill.
    pub fn best_of_n() -> ServeScenario {
        ServeScenario {
            name: "best_of_n",
            policy: BatchPolicy {
                chunk_tokens: 8,
                token_budget: 32,
                max_chunk_rows: 4,
                max_running: 8,
                decode_priority_threshold: 8,
            },
            mix: ScenarioMix::BestOfN,
        }
    }

    /// Fork fan-out of [`ServeScenario::best_of_n`].
    pub const BEST_OF_N: usize = 4;

    /// Fault-recovery storm for the resilience gate: eight
    /// single-chunk prompts with long generations, so the whole
    /// population is deep in decode when the gate injects a launch
    /// fault. The gate runs the population fault-free as the baseline,
    /// then re-runs it across a worker death — salvage vs
    /// reprefill-everything — and gates on bit-identical tokens plus
    /// the deterministic `reprefill_tokens` / `bytes_migrated`
    /// counters (`BENCH_resilience.json`).
    pub fn fault_storm() -> ServeScenario {
        ServeScenario {
            name: "fault_storm",
            policy: BatchPolicy {
                chunk_tokens: 6,
                token_budget: 16,
                max_chunk_rows: 2,
                max_running: 8,
                decode_priority_threshold: 8,
            },
            mix: ScenarioMix::FaultStorm,
        }
    }

    /// Requests in [`ServeScenario::fault_storm`].
    pub const FAULT_STORM_REQUESTS: u64 = 8;

    /// Admission-overload storm for the frontend gate: a tight policy
    /// (4 running slots, 16-token budget) hit with ~10× its sustainable
    /// load. Each [`ServeScenario::OVERLOAD_WINDOWS`]-window schedule
    /// delivers [`ServeScenario::OVERLOAD_BATCH_PER_WINDOW`] batch-class
    /// 32-token prompts plus one interactive 96-token prompt per
    /// [`ServeScenario::OVERLOAD_WINDOW_TICKS`]-tick window — the
    /// window's token capacity (16 × 12 = 192) fits roughly one
    /// interactive and one batch prompt, so FIFO admission drowns the
    /// interactive class while share-based admission sheds the excess
    /// batch traffic. **Not** part of [`ServeScenario::all`]: the
    /// trajectory artifact's scenario matrix stays at eight rows.
    pub fn overload() -> ServeScenario {
        ServeScenario {
            name: "overload",
            policy: BatchPolicy {
                chunk_tokens: 16,
                token_budget: 16,
                max_chunk_rows: 2,
                max_running: 4,
                decode_priority_threshold: 4,
            },
            mix: ScenarioMix::Overload,
        }
    }

    /// Admission-window length (submit-loop ticks) in the overload
    /// storm; one interactive request arrives per window.
    pub const OVERLOAD_WINDOW_TICKS: u64 = 12;

    /// Windows in the overload schedule.
    pub const OVERLOAD_WINDOWS: u64 = 20;

    /// Batch-class arrivals per window (ticks +0..+8 within the
    /// window; the interactive arrival lands at +4).
    pub const OVERLOAD_BATCH_PER_WINDOW: u64 = 9;

    /// Interactive prompt length in the overload storm.
    pub const OVERLOAD_HIGH_PROMPT: usize = 96;

    /// Batch prompt length in the overload storm.
    pub const OVERLOAD_LOW_PROMPT: usize = 32;

    /// Generation length for every overload request.
    pub const OVERLOAD_NEW_TOKENS: usize = 4;

    /// The full deterministic overload arrival schedule: per window,
    /// nine batch prompts at ticks +0..+8 and one interactive prompt
    /// at tick +4, sorted by (tick, id). Ids are dense 0..200 in
    /// generation order (batch ids of a window precede its interactive
    /// id), so the id order at a shared tick matches generation order.
    pub fn overload_arrivals(vocab: usize) -> Vec<OverloadArrival> {
        let v = vocab as i32;
        let mut out = Vec::new();
        let mut id: u64 = 0;
        for w in 0..Self::OVERLOAD_WINDOWS {
            let base = w * Self::OVERLOAD_WINDOW_TICKS;
            for k in 0..Self::OVERLOAD_BATCH_PER_WINDOW {
                out.push(OverloadArrival {
                    tick: base + k,
                    class: 2, // frontend::Priority::Batch
                    req: Request {
                        id,
                        prompt: (0..Self::OVERLOAD_LOW_PROMPT as i32)
                            .map(|x| (x * 7 + id as i32 + 1) % v)
                            .collect(),
                        max_new_tokens: Self::OVERLOAD_NEW_TOKENS,
                    },
                });
                id += 1;
            }
            out.push(OverloadArrival {
                tick: base + 4,
                class: 0, // frontend::Priority::Interactive
                req: Request {
                    id,
                    prompt: (0..Self::OVERLOAD_HIGH_PROMPT as i32)
                        .map(|x| (x * 11 + id as i32 + 3) % v)
                        .collect(),
                    max_new_tokens: Self::OVERLOAD_NEW_TOKENS,
                },
            });
            id += 1;
        }
        out.sort_by_key(|a| (a.tick, a.req.id));
        out
    }

    /// The token history a completed turn's state summarizes: the
    /// prompt plus every *engine-consumed* reply token. The final
    /// sampled token was never fed back (it is the pending next-step
    /// input), so it is excluded — including it in a follow-up prompt
    /// makes it one of the *new* tokens that turn prefills.
    pub fn session_history(prompt: &[i32], reply: &[i32]) -> Vec<i32> {
        let mut h = prompt.to_vec();
        if !reply.is_empty() {
            h.extend_from_slice(&reply[..reply.len() - 1]);
        }
        h
    }

    /// A follow-up turn's prompt: the previous turn (prompt + full
    /// reply) extended with `fresh` deterministic new tokens — a strict
    /// extension of [`ServeScenario::session_history`], as a real chat
    /// client resubmitting the conversation would produce. Shared by
    /// the snapshot gate, `serve_mamba --sessions`, and the conformance
    /// tests so the turn-2 contract is defined once.
    pub fn follow_up_prompt(prompt: &[i32], reply: &[i32], fresh: usize, vocab: usize) -> Vec<i32> {
        let v = vocab as i32;
        let mut p = prompt.to_vec();
        p.extend_from_slice(reply);
        for x in 0..fresh as i32 {
            p.push((x * 5 + 3) % v);
        }
        p
    }

    /// The scenarios the planner CI gates run on.
    pub fn bundled() -> Vec<ServeScenario> {
        vec![
            ServeScenario::prefill_heavy(),
            ServeScenario::decode_heavy(),
            ServeScenario::interference(),
        ]
    }

    /// Every bundled scenario, in the fixed order the perf-trajectory
    /// artifact (`BENCH_trajectory.json`) reports them. One scenario ×
    /// counter matrix over this list is the repo's consolidated view of
    /// serving behaviour across all phase regimes — keep the order
    /// stable so trajectory diffs line up across commits.
    pub fn all() -> Vec<ServeScenario> {
        vec![
            ServeScenario::prefill_heavy(),
            ServeScenario::decode_heavy(),
            ServeScenario::interference(),
            ServeScenario::sharded_skew(),
            ServeScenario::chunk_heavy(),
            ServeScenario::multi_turn(),
            ServeScenario::best_of_n(),
            ServeScenario::fault_storm(),
        ]
    }

    /// The scenario's deterministic request mix for a `vocab`-sized
    /// model.
    pub fn requests(&self, vocab: usize) -> Vec<Request> {
        let v = vocab as i32;
        match self.mix {
            ScenarioMix::PrefillHeavy => (0..4)
                .map(|i| Request {
                    id: i,
                    prompt: (0..4096).map(|x| (x + i as i32) % v).collect(),
                    max_new_tokens: 1,
                })
                .collect(),
            ScenarioMix::DecodeHeavy => (0..8)
                .map(|i| Request {
                    id: i,
                    prompt: vec![(i % 7) as i32 + 1; 3],
                    max_new_tokens: 48,
                })
                .collect(),
            ScenarioMix::ShardedSkew => (0..7)
                .map(|i| Request {
                    id: i,
                    prompt: (0..16).map(|x| (x * 7 + i as i32 + 1) % v).collect(),
                    max_new_tokens: 48,
                })
                .collect(),
            ScenarioMix::ChunkHeavy => (0..4)
                .map(|i| Request {
                    id: i,
                    // 7 chunks of exactly CHUNK_HEAVY_LEN tokens each.
                    prompt: (0..7 * Self::CHUNK_HEAVY_LEN as i32)
                        .map(|x| (x * 3 + i as i32 + 2) % v)
                        .collect(),
                    max_new_tokens: 4,
                })
                .collect(),
            ScenarioMix::MultiTurn => (0..Self::MULTI_TURN_SESSIONS)
                .map(|i| Request {
                    id: i,
                    // Turn 1 of session i: 24 tokens, 8-token reply.
                    prompt: (0..24).map(|x| (x * 11 + i as i32 * 3 + 1) % v).collect(),
                    max_new_tokens: 8,
                })
                .collect(),
            ScenarioMix::BestOfN => vec![Request {
                id: 0,
                // One shared prefill; the gate forks its session N ways
                // with max_new_tokens 1, so the stored snapshot is the
                // state right after the prompt.
                prompt: (0..32).map(|x| (x * 13 + 5) % v).collect(),
                max_new_tokens: 1,
            }],
            ScenarioMix::FaultStorm => (0..Self::FAULT_STORM_REQUESTS)
                .map(|i| Request {
                    id: i,
                    // One 6-token chunk each (== the policy's chunk
                    // size), generations long enough that nobody
                    // completes before the gate's fault tick.
                    prompt: (0..6).map(|x| (x * 7 + i as i32 * 3 + 2) % v).collect(),
                    max_new_tokens: 20,
                })
                .collect(),
            ScenarioMix::Overload => Self::overload_arrivals(vocab)
                .into_iter()
                .map(|a| a.req)
                .collect(),
            ScenarioMix::Interference => {
                let mut reqs: Vec<Request> = (0..6)
                    .map(|i| Request {
                        id: i,
                        prompt: vec![(i % 7) as i32 + 1; 4],
                        max_new_tokens: 64,
                    })
                    .collect();
                reqs.push(Request {
                    id: 99,
                    prompt: (0..512).map(|x| x % v).collect(),
                    max_new_tokens: 4,
                });
                reqs
            }
        }
    }

    /// `serve_mamba --mock`'s mixed traffic: mostly short prompts, with
    /// every fourth request a long prompt that spans many chunk ticks.
    pub fn mixed_traffic(n_requests: usize, vocab: usize) -> Vec<Request> {
        let mut short = WorkloadGen::new(7, vocab, 6, 2, 24).with_prompt_range(2, 12);
        (0..n_requests)
            .map(|i| {
                let mut r = short.next_request();
                if i % 4 == 3 {
                    // A long prompt: 10+ chunks at the default size.
                    r.prompt = (0..48).map(|x| (x + i as i32) % vocab as i32).collect();
                }
                r
            })
            .collect()
    }
}

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10.3?} mean  {:>10.3?} p50  {:>10.3?} p95  ({} iters)",
            self.name, self.mean, self.p50, self.p95, self.iters
        )
    }

    /// Machine-readable JSON object for `BENCH_*.json` outputs
    /// (rendered by the in-tree [`crate::util::JsonValue`] emitter).
    pub fn json(&self) -> crate::util::JsonValue {
        let mut o = crate::util::JsonValue::obj();
        o.set("name", self.name.as_str())
            .set("iters", self.iters as u64)
            .set("mean_ns", self.mean.as_nanos() as u64)
            .set("p50_ns", self.p50.as_nanos() as u64)
            .set("p95_ns", self.p95.as_nanos() as u64);
        o
    }
}

/// Time `f` for at least `min_iters` iterations and `min_time`.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_config(name, 3, 10, Duration::from_millis(300), &mut f)
}

/// Fully-parameterized variant.
pub fn bench_config<F: FnMut()>(
    name: &str,
    warmup: u32,
    min_iters: u32,
    min_time: Duration,
    f: &mut F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::new();
    let started = Instant::now();
    while samples.len() < min_iters as usize || started.elapsed() < min_time {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() > 10_000 {
            break;
        }
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let mean = total / samples.len() as u32;
    let p = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
    BenchResult {
        name: name.to_string(),
        iters: samples.len() as u32,
        mean,
        p50: p(0.5),
        p95: p(0.95),
    }
}

/// Prevent the optimizer from discarding a value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_covers_every_scenario_with_unique_names() {
        let all = ServeScenario::all();
        assert_eq!(all.len(), 8);
        let names: std::collections::BTreeSet<_> = all.iter().map(|s| s.name).collect();
        assert_eq!(names.len(), all.len(), "duplicate scenario name");
        for b in ServeScenario::bundled() {
            assert!(names.contains(b.name), "bundled scenario {} missing from all()", b.name);
        }
    }

    #[test]
    fn scenarios_are_deterministic_and_well_formed() {
        for sc in ServeScenario::all() {
            let a = sc.requests(17);
            let b = sc.requests(17);
            assert!(!a.is_empty());
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.prompt, y.prompt);
                assert_eq!(x.max_new_tokens, y.max_new_tokens);
                assert!(!x.prompt.is_empty());
                assert!(x.max_new_tokens >= 1);
            }
        }
        let m = ServeScenario::mixed_traffic(24, 17);
        assert_eq!(m.len(), 24);
        assert_eq!(m, ServeScenario::mixed_traffic(24, 17));
        assert!(m.iter().any(|r| r.prompt.len() >= 48), "long prompts present");
    }

    #[test]
    fn follow_up_prompt_strictly_extends_session_history() {
        let prompt: Vec<i32> = (0..24).collect();
        let reply = vec![3, 1, 4, 1, 5];
        let history = ServeScenario::session_history(&prompt, &reply);
        assert_eq!(history.len(), prompt.len() + reply.len() - 1, "last token never fed back");
        let fresh = 6;
        let p2 = ServeScenario::follow_up_prompt(&prompt, &reply, fresh, 17);
        assert_eq!(p2, ServeScenario::follow_up_prompt(&prompt, &reply, fresh, 17));
        assert!(p2.len() > history.len());
        assert_eq!(&p2[..history.len()], &history[..], "history is a strict prefix");
        // New tokens the snapshot path must prefill: the un-fed final
        // reply token plus the fresh ones.
        assert_eq!(p2.len() - history.len(), fresh + 1);
        // Empty reply: the history is just the prompt.
        assert_eq!(ServeScenario::session_history(&prompt, &[]), prompt);
    }

    #[test]
    fn overload_schedule_is_deterministic_and_shaped() {
        let a = ServeScenario::overload_arrivals(17);
        let b = ServeScenario::overload_arrivals(17);
        let per_window =
            ServeScenario::OVERLOAD_BATCH_PER_WINDOW + 1;
        assert_eq!(a.len() as u64, ServeScenario::OVERLOAD_WINDOWS * per_window);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.tick, x.class, &x.req.prompt), (y.tick, y.class, &y.req.prompt));
        }
        // Ids are unique, ticks sorted, classes well formed.
        let ids: std::collections::BTreeSet<_> = a.iter().map(|r| r.req.id).collect();
        assert_eq!(ids.len(), a.len());
        assert!(a.windows(2).all(|w| w[0].tick <= w[1].tick));
        let interactive = a.iter().filter(|r| r.class == 0).count() as u64;
        assert_eq!(interactive, ServeScenario::OVERLOAD_WINDOWS);
        for r in &a {
            assert!(r.class == 0 || r.class == 2);
            let want = if r.class == 0 {
                ServeScenario::OVERLOAD_HIGH_PROMPT
            } else {
                ServeScenario::OVERLOAD_LOW_PROMPT
            };
            assert_eq!(r.req.prompt.len(), want);
            assert_eq!(r.req.max_new_tokens, ServeScenario::OVERLOAD_NEW_TOKENS);
        }
        // The storm is genuinely over capacity: each window's demand
        // (9×32 + 96 = 384 prompt tokens) is 2× its 192-token budget.
        let demand = ServeScenario::OVERLOAD_BATCH_PER_WINDOW as usize
            * ServeScenario::OVERLOAD_LOW_PROMPT
            + ServeScenario::OVERLOAD_HIGH_PROMPT;
        let capacity = (ServeScenario::overload().policy.token_budget
            * ServeScenario::OVERLOAD_WINDOW_TICKS as usize) as usize;
        assert!(demand >= 2 * capacity, "{demand} vs {capacity}");
    }

    #[test]
    fn bench_produces_ordered_percentiles() {
        let r = bench_config("noop", 1, 5, Duration::from_millis(1), &mut || {
            black_box(1 + 1);
        });
        assert!(r.iters >= 5);
        assert!(r.p50 <= r.p95);
        assert!(r.report().contains("noop"));
        // JSON output round-trips through the in-tree parser.
        let j = r.json().to_string();
        let parsed = crate::util::JsonValue::parse(&j).unwrap();
        assert_eq!(parsed.get("name").and_then(|v| v.as_str()), Some("noop"));
        assert!(parsed.get("mean_ns").and_then(|v| v.as_i64()).is_some());
    }
}
