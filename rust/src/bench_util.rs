//! Mini benchmark harness (no criterion in the vendored crate set):
//! warmup + timed iterations with mean / p50 / p95 and a throughput
//! hook. Used by `cargo bench` targets (harness = false).

use std::time::{Duration, Instant};

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10.3?} mean  {:>10.3?} p50  {:>10.3?} p95  ({} iters)",
            self.name, self.mean, self.p50, self.p95, self.iters
        )
    }

    /// Machine-readable JSON object for `BENCH_*.json` outputs
    /// (rendered by the in-tree [`crate::util::JsonValue`] emitter).
    pub fn json(&self) -> crate::util::JsonValue {
        let mut o = crate::util::JsonValue::obj();
        o.set("name", self.name.as_str())
            .set("iters", self.iters as u64)
            .set("mean_ns", self.mean.as_nanos() as u64)
            .set("p50_ns", self.p50.as_nanos() as u64)
            .set("p95_ns", self.p95.as_nanos() as u64);
        o
    }
}

/// Time `f` for at least `min_iters` iterations and `min_time`.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_config(name, 3, 10, Duration::from_millis(300), &mut f)
}

/// Fully-parameterized variant.
pub fn bench_config<F: FnMut()>(
    name: &str,
    warmup: u32,
    min_iters: u32,
    min_time: Duration,
    f: &mut F,
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::new();
    let started = Instant::now();
    while samples.len() < min_iters as usize || started.elapsed() < min_time {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() > 10_000 {
            break;
        }
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    let mean = total / samples.len() as u32;
    let p = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
    BenchResult {
        name: name.to_string(),
        iters: samples.len() as u32,
        mean,
        p50: p(0.5),
        p95: p(0.95),
    }
}

/// Prevent the optimizer from discarding a value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_percentiles() {
        let r = bench_config("noop", 1, 5, Duration::from_millis(1), &mut || {
            black_box(1 + 1);
        });
        assert!(r.iters >= 5);
        assert!(r.p50 <= r.p95);
        assert!(r.report().contains("noop"));
        // JSON output round-trips through the in-tree parser.
        let j = r.json().to_string();
        let parsed = crate::util::JsonValue::parse(&j).unwrap();
        assert_eq!(parsed.get("name").and_then(|v| v.as_str()), Some("noop"));
        assert!(parsed.get("mean_ns").and_then(|v| v.as_i64()).is_some());
    }
}
