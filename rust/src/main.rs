//! `mambalaya` — CLI for the Mambalaya reproduction.
//!
//! Subcommands:
//!   cascade    dump the Mamba cascade (table or Graphviz dot)
//!   fuse       show fusion groups per variant
//!   analyze    evaluate a layer under a variant on the Mambalaya model
//!   autotune   sweep the (decode × prefill) grid into a PlanTable artifact
//!   reproduce  regenerate a paper table/figure (--exp table1|...|fig15|all)
//!   serve      run the serving coordinator on the AOT artifacts
//!   verify     static verifier: plan legality + traffic audit + donation
//!              safety + source lint, written to VERIFY_report.json
//!   help       this text

use std::io::Write as _;

use mambalaya::arch::ArchSpec;
use mambalaya::cascade::{mamba1, mamba2, ModelConfig};
use mambalaya::coordinator::{BatchPolicy, WorkloadGen};
use mambalaya::einsum::display::{cascade_dot, cascade_table};
use mambalaya::fusion::{stitch, FusionVariant};
use mambalaya::model::{evaluate, ExecOptions};
use mambalaya::report;
use mambalaya::roofline::{ascii_chart, timeline};
use mambalaya::runtime::MambaEngine;
use mambalaya::util::Args;

fn main() {
    let args = Args::from_env();
    let code = match args.subcommand() {
        Some("cascade") => cmd_cascade(&args),
        Some("fuse") => cmd_fuse(&args),
        Some("analyze") => cmd_analyze(&args),
        Some("autotune") => cmd_autotune(&args),
        Some("reproduce") => cmd_reproduce(&args),
        Some("serve") => cmd_serve(&args),
        Some("verify") => cmd_verify(&args),
        _ => {
            print!("{}", HELP);
            0
        }
    };
    std::process::exit(code);
}

const HELP: &str = "\
mambalaya — einsum-based fusion optimizations on state-space models (reproduction)

USAGE: mambalaya <SUBCOMMAND> [OPTIONS]

  cascade   --model 370m|2.8b|tiny [--seq N] [--mamba2] [--dot]
  fuse      --model 370m [--seq N] [--variant V] [--cascade FILE.einsum]
  analyze   --model 370m [--seq N] [--batch B] [--variant V] [--pipelined] [--chart]
  autotune  [--model 370m] [--quick] [--out PLAN_TABLE.json]
            (offline fusion-plan sweep; serve with --plan table:<file>)
  reproduce --exp table1|table2|table3|fig2|fig9|fig10|fig12|fig13|fig14|fig15|all
            [--model 370m] [--seq N] [--batch B] [--out-dir results]
  serve     [--artifacts DIR] [--requests N] [--gen-lo N] [--gen-hi N] [--workers W]
            [--chunk-tokens N] [--token-budget N] [--plan SPEC] [--rebalance]
            (continuous-batching knobs; chunk-tokens 0 = monolithic prefill;
            plan SPEC = static:<variant>|adaptive|table:<path>; --rebalance
            lets the slot-aware router migrate in-flight requests between
            worker shards by moving resident state, never re-prefilling)
  verify    [--seq N] [--batch B] [--out VERIFY_report.json] [--src DIR] [--no-lint]
            (static verification of every fusion plan on every cascade —
            legality, liveness-exact traffic audit vs the cost model,
            donation safety — plus the rust/src source lint; exits
            non-zero on any Error finding)
";

fn model(args: &Args) -> ModelConfig {
    ModelConfig::by_name(args.get_or("model", "370m")).unwrap_or_else(|| {
        eprintln!("unknown model; use 130m|370m|1.4b|2.8b|tiny");
        std::process::exit(2);
    })
}

fn cmd_cascade(args: &Args) -> i32 {
    let cfg = model(args);
    let seq = args.get_u64("seq", 1024);
    let c = if args.flag("mamba2") {
        mamba2::build(&cfg, seq, 1)
    } else {
        mamba1::build(&cfg, seq, 1)
    };
    if let Err(e) = c.validate() {
        eprintln!("cascade invalid: {e}");
        return 1;
    }
    if args.flag("dot") {
        print!("{}", cascade_dot(&c));
    } else {
        print!("{}", cascade_table(&c));
    }
    0
}

fn cmd_fuse(args: &Args) -> i32 {
    // `--cascade FILE` applies the taxonomy to a user-supplied EDGE
    // cascade (see einsum::parser for the format); default is Mamba-1.
    let c = if let Some(path) = args.get("cascade") {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("reading {path}: {e}");
                return 1;
            }
        };
        match mambalaya::einsum::parse_cascade(path, &text) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("parsing {path}: {e:#}");
                return 1;
            }
        }
    } else {
        let cfg = model(args);
        let seq = args.get_u64("seq", 1024);
        mamba1::build(&cfg, seq, 1)
    };
    let variants: Vec<FusionVariant> = match args.get("variant") {
        Some(v) => match FusionVariant::parse(v) {
            Some(v) => vec![v],
            None => {
                eprintln!("unknown variant {v}");
                return 2;
            }
        },
        None => FusionVariant::all().to_vec(),
    };
    for v in variants {
        let plan = stitch(&c, v);
        println!("{:<12} {} groups", v.name(), plan.groups.len());
        for g in &plan.groups {
            let ids: Vec<String> = g.einsums.iter().map(|i| i.to_string()).collect();
            let classes: Vec<String> =
                g.classes_used().iter().map(|c| c.to_string()).collect();
            println!(
                "  [{}] stationary {} classes {{{}}}{}",
                ids.join(","),
                g.stationary,
                classes.join(","),
                if g.rd_bridged { " (RD-bridged)" } else { "" }
            );
        }
    }
    0
}

fn cmd_analyze(args: &Args) -> i32 {
    let cfg = model(args);
    let seq = args.get_u64("seq", 4096);
    let batch = args.get_u64("batch", 1);
    let arch = ArchSpec::mambalaya();
    let c = mamba1::build(&cfg, seq, batch);
    let variants: Vec<FusionVariant> = match args.get("variant") {
        Some(v) => vec![FusionVariant::parse(v).expect("variant")],
        None => FusionVariant::all().to_vec(),
    };
    let opts = ExecOptions { pipelined: args.flag("pipelined"), ..Default::default() };
    let base = evaluate(&c, &stitch(&c, FusionVariant::Unfused), &arch, &opts);
    println!(
        "{} seq={seq} batch={batch} | machine balance {:.1} flop/B",
        cfg.name,
        arch.machine_balance()
    );
    for v in variants {
        let cost = evaluate(&c, &stitch(&c, v), &arch, &opts);
        println!(
            "{:<12} latency {:>12} cyc ({:.3} ms) speedup {:>5.2}x  OI {:>6.1}  traffic {:>8} MiB (inter {} MiB)",
            v.name(),
            cost.latency,
            cost.latency_secs(&arch) * 1e3,
            base.latency as f64 / cost.latency as f64,
            cost.intensity(),
            cost.traffic.total() >> 20,
            cost.traffic.inter() >> 20,
        );
        if args.flag("chart") {
            print!("{}", ascii_chart(&timeline(&cost, &arch), 72));
        }
    }
    0
}

fn cmd_autotune(args: &Args) -> i32 {
    let cfg = model(args);
    let quick = args.flag("quick");
    let out = args.get_or("out", "PLAN_TABLE.json");
    let arch = ArchSpec::mambalaya();
    let table = mambalaya::planner::autotune(&cfg, &arch, quick);
    println!(
        "autotuned {} ({} grid): {} × {} cells",
        cfg.name,
        if quick { "quick" } else { "full" },
        table.decode_axis.len(),
        table.prefill_axis.len()
    );
    for (d, &rows) in table.decode_axis.iter().enumerate() {
        for (p, &toks) in table.prefill_axis.iter().enumerate() {
            let cell = table.cells[d][p];
            println!(
                "  decode={rows:<3} prefill={toks:<5} → {:<12} ({} cyc, {} B)",
                cell.choice.name(),
                cell.cycles,
                cell.bytes
            );
        }
    }
    if let Err(e) = table.save(out) {
        eprintln!("{e:#}");
        return 1;
    }
    println!("wrote {out} (serve with --plan table:{out})");
    0
}

fn cmd_verify(args: &Args) -> i32 {
    let seq = args.get_u64("seq", 512);
    let batch = args.get_u64("batch", 1);
    let out = args.get_or("out", "VERIFY_report.json");
    // The lint walks the source tree; --src overrides for out-of-tree
    // checkouts, CARGO_MANIFEST_DIR (the repo root) is the default.
    let report = if args.flag("no-lint") {
        mambalaya::verify::verify_cascades_with(seq, batch)
    } else {
        let root = args
            .get("src")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(|| std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")));
        mambalaya::verify::verify_all(&root, seq, batch)
    };
    println!(
        "verified {} (cascade, plan) pairs; lint scanned {} files",
        report.plans.len(),
        report.lint_files
    );
    for f in report.findings.iter().chain(report.lint_findings.iter()) {
        match f.severity {
            mambalaya::verify::Severity::Error | mambalaya::verify::Severity::Warn => {
                println!("{f}")
            }
            mambalaya::verify::Severity::Info => {}
        }
    }
    println!(
        "findings: {} error(s), {} warn(s), {} info(s)",
        report.errors(),
        report.warns(),
        report.infos()
    );
    if let Err(e) = std::fs::write(out, format!("{}\n", report.to_json())) {
        eprintln!("cannot write {out}: {e}");
        return 1;
    }
    println!("wrote {out}");
    if report.errors() > 0 {
        1
    } else {
        0
    }
}

fn cmd_reproduce(args: &Args) -> i32 {
    let cfg = model(args);
    let seq = args.get_u64("seq", 16384);
    let batch = args.get_u64("batch", 64);
    let exp = args.get_or("exp", "all");
    let out_dir = args.get("out-dir").map(|s| s.to_string());
    let mut outputs: Vec<(&str, String, String)> = Vec::new();

    let run = |name: &str| exp == "all" || exp == name;
    if run("table1") {
        let (t, c) = report::table1_report(&cfg, seq, batch);
        outputs.push(("table1", t, c));
    }
    if run("table2") {
        let (t, c) = report::table2_report();
        outputs.push(("table2", t, c));
    }
    if run("table3") {
        let (t, c) = report::table3_report();
        outputs.push(("table3", t, c));
    }
    if run("fig2") {
        let (t, c) = report::fig2_report(&cfg, seq, batch);
        outputs.push(("fig2", t, c));
    }
    if run("fig9") {
        let (t, c) = report::fig9_report(&cfg, seq);
        outputs.push(("fig9", t, c));
    }
    if run("fig10") {
        let (t, c) = report::fig10_report(&cfg, seq, batch);
        outputs.push(("fig10", t, c));
    }
    if run("fig12") {
        let (t, c) = report::fig12_report(&cfg);
        outputs.push(("fig12", t, c));
    }
    if run("fig13") {
        let (t, c) = report::fig13_report(&cfg);
        outputs.push(("fig13", t, c));
    }
    if run("fig14") {
        let (t, c) = report::fig14_report(&cfg, seq, batch);
        outputs.push(("fig14", t, c));
    }
    if run("fig15") {
        let (t, c) = report::fig15_report(&cfg, seq, batch);
        outputs.push(("fig15", t, c));
    }
    if outputs.is_empty() {
        eprintln!("unknown experiment {exp}");
        return 2;
    }
    for (name, text, csv) in &outputs {
        println!("{text}");
        if let Some(dir) = &out_dir {
            std::fs::create_dir_all(dir).expect("mkdir");
            let path = format!("{dir}/{name}.csv");
            let mut f = std::fs::File::create(&path).expect("create");
            f.write_all(csv.as_bytes()).expect("write");
            println!("  → wrote {path}");
        }
    }
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let dir = args.get_or("artifacts", "artifacts").to_string();
    let n = args.get_u64("requests", 16) as usize;
    let gen_lo = args.get_u64("gen-lo", 4) as usize;
    let gen_hi = args.get_u64("gen-hi", 16) as usize;
    let workers = args.get_u64("workers", 1) as usize;
    let policy = BatchPolicy::from_args(args);
    let spec = match mambalaya::planner::PlanSpec::parse(args.get_or("plan", "adaptive")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e:#}");
            return 2;
        }
    };

    let manifest = match mambalaya::runtime::Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e:#}");
            return 1;
        }
    };
    println!(
        "serving {} ({} layers, E={}, vocab={}) from {dir} with {workers} worker(s), plan {}",
        manifest.model,
        manifest.n_layer,
        manifest.d_model,
        manifest.vocab,
        spec.name()
    );
    let mut gen =
        WorkloadGen::new(2024, manifest.vocab, manifest.prefill_len, gen_lo, gen_hi);
    let reqs: Vec<_> = (0..n).map(|_| gen.next_request()).collect();

    let factories: Vec<_> = (0..workers.max(1))
        .map(|_| {
            let d = dir.clone();
            move || MambaEngine::load(&d)
        })
        .collect();
    let mut server =
        mambalaya::coordinator::Server::start_planned(factories, policy, spec);
    let rxs: Vec<_> = reqs.into_iter().map(|r| server.submit(r)).collect();
    if args.flag("rebalance") {
        // Slot-aware router passes while the workload drains: migrate
        // in-flight requests off hot shards by moving resident state.
        // Skew develops as requests complete unevenly, so keep passing
        // until the workers drain, not until the first empty plan.
        for _ in 0..10_000 {
            let in_flight: usize =
                server.loads().iter().map(|l| l.running + l.waiting).sum();
            if in_flight == 0 {
                break;
            }
            server.rebalance();
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
    }
    let mut total_tokens = 0;
    for rx in rxs {
        match rx.recv() {
            Ok(resp) => total_tokens += resp.tokens.len(),
            Err(e) => {
                eprintln!("response lost: {e}");
                return 1;
            }
        }
    }
    println!("completed {n} requests, {total_tokens} tokens");
    for r in server.reports() {
        println!("{r}");
    }
    let t = server.traffic();
    println!(
        "plan: switches={} predicted={}cyc modeled={}cyc | state traffic: gathered={}B scattered={}B \
         | migration: {} moves, {}B migrated, {} reprefills avoided",
        t.plan_switches,
        t.predicted_cycles,
        t.modeled_cycles,
        t.bytes_gathered,
        t.bytes_scattered,
        t.migrations,
        t.bytes_migrated,
        t.reprefills_avoided
    );
    server.shutdown();
    0
}
