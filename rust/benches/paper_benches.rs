//! `cargo bench` target: regenerate every paper table/figure and time
//! each regeneration (the benches double as the experiment harness —
//! DESIGN.md §6 maps each entry to its table/figure).
//!
//! Absolute paper numbers come from a Timeloop-modeled DSA; per the
//! reproduction brief we check *shape* (who wins, rough factors,
//! crossovers). EXPERIMENTS.md records paper-vs-measured.

use mambalaya::bench_util::{bench, black_box};
use mambalaya::cascade::ModelConfig;
use mambalaya::report;

fn main() {
    let cfg = ModelConfig::mamba_370m();
    let seq = 16384;
    let batch = 64;

    println!("== paper experiment regeneration (mamba-370m, I=16384×64) ==\n");

    let results = vec![
        bench("table1: best-unfused traffic breakdown", || {
            black_box(report::table1_report(&cfg, seq, batch));
        }),
        bench("table2: fusion taxonomy matrix", || {
            black_box(report::table2_report());
        }),
        bench("table3: architecture configuration", || {
            black_box(report::table3_report());
        }),
        bench("fig2: roofline unfused vs ideal", || {
            black_box(report::fig2_report(&cfg, seq, batch));
        }),
        bench("fig9: fusion groups per variant", || {
            black_box(report::fig9_report(&cfg, seq));
        }),
        bench("fig10: utilization timeline per variant", || {
            black_box(report::fig10_report(&cfg, seq, batch));
        }),
        bench("fig12: end-to-end scenario sweep", || {
            black_box(report::fig12_report(&cfg));
        }),
        bench("fig13: vs MARCA-like / Geens-like", || {
            black_box(report::fig13_report(&cfg));
        }),
        bench("fig14: inter/intra traffic per variant", || {
            black_box(report::fig14_report(&cfg, seq, batch));
        }),
        bench("fig15: baseline utilization timelines", || {
            black_box(report::fig15_report(&cfg, seq, batch));
        }),
    ];
    for r in &results {
        println!("{}", r.report());
    }

    // Headline numbers, printed for the record.
    println!("\n== headline check ==");
    let (t13, _) = report::fig13_report(&cfg);
    for line in t13.lines().filter(|l| l.contains("geomean") || l.contains("summarize")) {
        println!("{line}");
    }
    let (t2, _) = report::fig2_report(&cfg, seq, batch);
    for line in t2.lines().filter(|l| l.contains("ideal-fusion")) {
        println!("{line}");
    }
}
