//! `cargo bench` target: microbenchmarks of the library's hot paths —
//! the inputs to the §Perf optimization pass (EXPERIMENTS.md §Perf).
//!
//! * cascade construction + validation
//! * pairwise classification over all pairs
//! * greedy stitching (all variants)
//! * analytical evaluation (the DSE inner loop)
//! * pass analysis
//! * coordinator: reference gather+install vs resident in-place step,
//!   mock decode step, full serve
//! * coordinator: long-prompt interference, chunked vs monolithic
//!   prefill, resident vs reference state path — with the
//!   deterministic state-traffic counters gating the perf trajectory
//! * util: JSON parse (manifest-sized doc)
//!
//! Modes:
//! * default — full microbench suite + interference scenario;
//! * `-- --quick` — interference scenario only (deterministic, fast):
//!   the CI gate. Both modes write machine-readable
//!   `BENCH_hotpath.json` (ticks/sec plus the traffic counters) and
//!   assert the resident path moves ≥ 10× fewer state bytes than the
//!   reference path — a counter gate, not a wall-time gate. Both modes
//!   also run the planner gate (`BENCH_planner.json`), the sharding
//!   gate (`BENCH_sharding.json`), the engine-API gate
//!   (`BENCH_engine_api.json`: caps-declared fused varlen launch = 1
//!   device call per tick vs the decomposition's lockstep cost), the
//!   snapshot gate (`BENCH_snapshot.json`: session snapshot cache —
//!   multi-turn follow-ups prefill only their new tokens, best-of-N
//!   forks decode N ways from one prefill, token-identical to full
//!   re-prefill) and the resilience gate (`BENCH_resilience.json`:
//!   fault-injected engine failures — salvage from a poisoned
//!   scheduler replays only the rows the failing launch touched,
//!   beating reprefill-everything ≥ 5× on replayed-token counters;
//!   the threaded server respawns a fail-once worker within its
//!   restart cap bit-identically, and a permanent fault ends in
//!   exactly one terminal error per sink, never a dropped channel)
//!   and the trajectory gate (`BENCH_trajectory.json`: all eight
//!   bundled scenarios through one harness, emitting a scenario ×
//!   counter matrix plus tick-unit latency percentiles — every value
//!   deterministic, proven by running each scenario twice and
//!   requiring identical rows)
//!   and the frontend gate (`BENCH_frontend.json`: the overload storm
//!   at ~10× the interactive class's demand — SLO-aware admission
//!   holds interactive p99 TTFT within 2× the unloaded baseline while
//!   FIFO no-admission degrades ≥ 5×, every shed counted; plus real
//!   TCP conformance — concurrent clients through `frontend::serve`
//!   get exactly one terminal frame per submitted id, shed requests
//!   included, bit-identical to in-process `serve_all`).
//!
//! Every gate additionally enforces the **reconciliation property**:
//! the drained request-lifecycle trace ([`mambalaya::obs`]) must
//! account for the independently maintained traffic counters exactly —
//! Σ `Launch.device_calls` == `device_calls`, Σ staged bytes, migration
//! /snapshot/replay counts, completions — with exactly one terminal
//! event per request span.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use mambalaya::arch::ArchSpec;
use mambalaya::bench_util::{bench_config, black_box, BenchResult, ServeScenario};
use mambalaya::cascade::{mamba1, ModelConfig};
use mambalaya::coordinator::{
    serve_all, BatchPolicy, LatencyReport, Request, Response, Scheduler, Server, StateArena,
    StatePath, TrafficSnapshot, WorkloadGen, PRIORITY_CLASSES,
};
use mambalaya::frontend::{
    run_client, serve, AdmissionConfig, AdmissionController, FrontendConfig, LoadSignal, Priority,
};
use mambalaya::fusion::{classify_cascade, stitch, FusionVariant};
use mambalaya::model::{analyze_scope, evaluate, ExecOptions};
use mambalaya::planner::{PlanChoice, Planner, PlanSpec};
use mambalaya::runtime::{
    Donation, EngineCaps, Executor, FaultInjector, FaultPlan, LaunchSpec, MixedBatch, MockEngine,
    Phase, Segment, StateSlabs, Workspace,
};
use mambalaya::obs::{assemble_spans, reconcile, TraceEvent, TraceRecord};
use mambalaya::util::{Args, JsonValue};

fn b<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_config(name, 3, 20, Duration::from_millis(200), &mut f)
}

/// Drain a scheduler's trace ring and enforce the reconciliation
/// property against its own counters: the check is only meaningful over
/// a complete stream, so a lossy ring fails the gate outright.
fn reconcile_scheduler<E: Executor>(gate: &str, s: &mut Scheduler<E>) -> Vec<TraceRecord> {
    assert_eq!(s.trace_dropped(), 0, "{gate}: trace ring overflowed");
    let trace = s.take_trace();
    reconcile(&trace, &s.metrics().traffic_snapshot())
        .unwrap_or_else(|e| panic!("{gate}: trace/counter reconciliation failed: {e}"));
    trace
}

/// One interference run: six short-prompt decoders ride along while a
/// 512-token prompt prefills (the shared `ServeScenario::interference`
/// mix). Returns the scheduler's outcome for the JSON report and the
/// gate assertions.
struct InterferenceOutcome {
    name: &'static str,
    ticks: u64,
    max_tick_tokens: u64,
    ttft_p99_ms: f64,
    short_latency_max_ms: f64,
    wall: Duration,
    ticks_per_sec: f64,
    traffic: TrafficSnapshot,
    tokens: Vec<Vec<i32>>,
}

fn interference(name: &'static str, policy: BatchPolicy, path: StatePath) -> InterferenceOutcome {
    let vocab = MockEngine::new().manifest().vocab;
    let reqs = ServeScenario::interference().requests(vocab);

    let t0 = Instant::now();
    let mut s = Scheduler::with_path(MockEngine::new(), policy, path);
    for r in reqs {
        s.submit(r).unwrap();
    }
    let mut resps = s.run_until_drained().unwrap();
    let wall = t0.elapsed();
    resps.sort_by_key(|r| r.id);
    let short_max: f64 = resps
        .iter()
        .filter(|r| r.id != 99)
        .map(|r| r.total)
        .fold(0.0, f64::max);
    let tokens = resps.iter().map(|r| r.tokens.clone()).collect();
    reconcile_scheduler(name, &mut s);
    let met = s.metrics();
    InterferenceOutcome {
        name,
        ticks: met.ticks,
        max_tick_tokens: met.max_tick_tokens,
        ttft_p99_ms: met.ttft_pct(0.99) * 1e3,
        short_latency_max_ms: short_max * 1e3,
        wall,
        ticks_per_sec: met.ticks as f64 / wall.as_secs_f64().max(1e-9),
        traffic: met.traffic_snapshot(),
        tokens,
    }
}

fn outcome_json(o: &InterferenceOutcome) -> JsonValue {
    let mut j = JsonValue::obj();
    j.set("name", o.name)
        .set("ticks", o.ticks)
        .set("ticks_per_sec", (o.ticks_per_sec * 10.0).round() / 10.0)
        .set("max_tick_tokens", o.max_tick_tokens)
        .set("ttft_p99_ms", (o.ttft_p99_ms * 1e3).round() / 1e3)
        .set("bytes_gathered", o.traffic.bytes_gathered)
        .set("bytes_scattered", o.traffic.bytes_scattered)
        .set("padded_rows", o.traffic.padded_rows);
    j
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let quick = args.flag("quick");

    let mut results = Vec::new();
    if !quick {
        let cfg = ModelConfig::mamba_2_8b();
        let arch = ArchSpec::mambalaya();
        let c = mamba1::build(&cfg, 16384, 64);
        let plans: Vec<_> =
            FusionVariant::all().iter().map(|&v| stitch(&c, v)).collect();
        let opts = ExecOptions::default();

        results.push(b("cascade: build+validate mamba1/2.8b", || {
            let c = mamba1::build(&cfg, 16384, 64);
            black_box(c.validate().unwrap());
        }));
        results.push(b("fusion: classify all pairs", || {
            black_box(classify_cascade(&c));
        }));
        for v in FusionVariant::all() {
            results.push(b(&format!("fusion: stitch {}", v.name()), || {
                black_box(stitch(&c, v));
            }));
        }
        results.push(b("model: evaluate all 5 variants (DSE step)", || {
            for p in &plans {
                black_box(evaluate(&c, p, &arch, &opts));
            }
        }));
        results.push(b("model: pass analysis (full scope)", || {
            black_box(analyze_scope(&c, &(1..=24).collect::<Vec<_>>()));
        }));

        // Coordinator hot paths (mock engine → measures coordination
        // overhead, not model math). The pair below is the refactor's
        // before/after: the reference path packs fresh buffers per tick
        // (gather + engine copies + install), the resident path hands
        // the arena slab to the engine and copies nothing.
        let mock = MockEngine::new();
        let m = mock.manifest().clone();
        let (cp, sp) = (m.d_inner * (m.d_conv - 1), m.d_inner * m.d_state);
        let mut arena = StateArena::new(m.n_layer, cp, sp, 8);
        let seed = {
            let toks: Vec<i32> = (0..8 * m.prefill_len as i32).collect();
            mock.prefill(8, &toks).unwrap()
        };
        for s in 0..8u64 {
            arena.install_from_batch(s, 8, s as usize, &seed.conv_state, &seed.ssm_state);
        }
        let some_ids: Vec<Option<u64>> = (0..8).map(Some).collect();
        let decode_toks: Vec<i32> = (1..=8).collect();
        let ref_segs: Vec<Segment> =
            (0..8).map(|b| Segment { len: 1, row: b, phase: Phase::Decode }).collect();
        let mut ws_ref = Workspace::new();
        results.push(b("coordinator: reference gather+launch+install b=8", || {
            let (mut c8, mut s8) = arena.gather_rows(&some_ids);
            mock.launch(LaunchSpec {
                batch: MixedBatch::new(&ref_segs, &decode_toks).unwrap(),
                state: StateSlabs::new(&mut c8, &mut s8, 8, Donation::Retain),
                plan: None,
                ws: &mut ws_ref,
            })
            .unwrap();
            for s in 0..8u64 {
                arena.install_from_batch(s, 8, s as usize, &c8, &s8);
            }
            black_box(());
        }));
        let res_segs: Vec<Segment> = (0..8)
            .map(|s| Segment { len: 1, row: arena.row_of(s).unwrap(), phase: Phase::Decode })
            .collect();
        let mut ws = Workspace::new();
        results.push(b("coordinator: resident launch b=8", || {
            mock.launch(LaunchSpec {
                batch: MixedBatch::new(&res_segs, &decode_toks).unwrap(),
                state: arena.slabs(Donation::DonateInPlace),
                plan: None,
                ws: &mut ws,
            })
            .unwrap();
            black_box(ws.logits.len());
        }));
        let probe = MockEngine::new();
        let (conv0, ssm0) = (seed.conv_state.clone(), seed.ssm_state.clone());
        results.push(b("coordinator: mock decode step b=8", || {
            black_box(probe.decode(8, &decode_toks, &conv0, &ssm0).unwrap());
        }));
        results.push(b("coordinator: serve 16 requests (mock)", || {
            let mut gen = WorkloadGen::new(3, m.vocab, m.prefill_len, 4, 4);
            let reqs = (0..16).map(|_| gen.next_request()).collect();
            black_box(serve_all(|| Ok(MockEngine::new()), BatchPolicy::default(), reqs).unwrap());
        }));

        // Util.
        let manifest_text =
            std::fs::read_to_string("artifacts/manifest.json").unwrap_or_else(|_| {
                r#"{"a":[1,2,3],"b":{"c":1.5},"d":"xyz"}"#.repeat(1).to_string()
            });
        results.push(b("util: JSON parse (manifest)", || {
            black_box(JsonValue::parse(&manifest_text).unwrap());
        }));
    }

    // Mixed-traffic interference: six short-prompt sequences decode
    // while one 512-token prompt prefills. Chunked prefill bounds the
    // per-tick token cost to the budget (monolithic provably stalls a
    // full tick on the long prompt), and the resident state path
    // eliminates the per-tick gather/scatter traffic the reference
    // path pays. The counters are deterministic — same workload, same
    // bytes — so CI gates on them rather than on wall time.
    println!("== mixed-traffic interference (mock engine) ==");
    let chunked = ServeScenario::interference().policy;
    let monolithic = BatchPolicy { chunk_tokens: 0, token_budget: 1 << 20, ..chunked.clone() };
    let runs = [
        interference("chunked_resident", chunked.clone(), StatePath::Resident),
        interference("chunked_reference", chunked, StatePath::Reference),
        interference("monolithic_resident", monolithic, StatePath::Resident),
    ];
    for o in &runs {
        println!(
            "  {:<20} ticks={:<4} max_tick_tokens={:<6} ttft_p99={:>8.3}ms \
             short_latency_max={:>8.3}ms gathered={:<8} scattered={:<8} padded={:<4} wall={:>9.3?}",
            o.name,
            o.ticks,
            o.max_tick_tokens,
            o.ttft_p99_ms,
            o.short_latency_max_ms,
            o.traffic.bytes_gathered,
            o.traffic.bytes_scattered,
            o.traffic.padded_rows,
            o.wall,
        );
    }

    // Gate 1 (scheduling): chunked prefill respects the token budget;
    // monolithic admits the whole prompt into one tick.
    assert!(
        runs[0].max_tick_tokens <= 32,
        "chunked tick span {} > budget",
        runs[0].max_tick_tokens
    );
    assert!(
        runs[2].max_tick_tokens >= 512,
        "monolithic did not admit the whole prompt"
    );
    // Gate 2 (equivalence): residency changes no output.
    assert_eq!(
        runs[0].tokens, runs[1].tokens,
        "resident and reference paths diverged"
    );
    // Gate 3 (the perf acceptance bar): the resident path moves ≥ 10×
    // fewer state bytes than the pre-refactor reference — measured on
    // deterministic counters, not wall time.
    let resident_total = runs[0].traffic.bytes_gathered + runs[0].traffic.bytes_scattered;
    let reference_total = runs[1].traffic.bytes_gathered + runs[1].traffic.bytes_scattered;
    let ratio_floor = 10 * resident_total.max(1);
    assert!(
        reference_total >= ratio_floor,
        "traffic gate failed: reference {reference_total}B < 10x resident {resident_total}B"
    );

    // Machine-readable output for CI and trend tracking.
    let mut gate = JsonValue::obj();
    gate.set("traffic_ratio_min", 10u64)
        .set("resident_bytes_total", resident_total)
        .set("reference_bytes_total", reference_total)
        .set("pass", true);
    let mut doc = JsonValue::obj();
    doc.set("bench", "hotpath")
        .set("mode", if quick { "quick" } else { "full" })
        .set("interference", JsonValue::Arr(runs.iter().map(outcome_json).collect()))
        .set("gate", gate)
        .set("micro", JsonValue::Arr(results.iter().map(|r| r.json()).collect()));
    std::fs::write("BENCH_hotpath.json", doc.to_string())
        .expect("writing BENCH_hotpath.json");
    println!("wrote BENCH_hotpath.json (traffic gate: PASS)");

    planner_gate();
    sharding_gate();
    engine_api_gate();
    snapshot_gate();
    resilience_gate();
    trajectory_gate();
    frontend_gate();

    if !quick {
        println!("\n== hot-path microbenchmarks ==");
        for r in &results {
            println!("{}", r.report());
        }
    }
}

/// One scheduler run of a bundled scenario under a plan spec. The
/// adaptive runs use dwell 1 (pure per-bucket argmin), which is the
/// configuration the counter gate is exact for: the per-tick argmin of
/// the modeled cost can never exceed any fixed plan's cost on the same
/// ticks.
fn planner_run(sc: &ServeScenario, planner: Planner) -> (Vec<Vec<i32>>, TrafficSnapshot) {
    let vocab = MockEngine::new().manifest().vocab;
    let mut s = Scheduler::with_planner(
        MockEngine::new(),
        sc.policy.clone(),
        StatePath::Resident,
        planner,
    );
    for r in sc.requests(vocab) {
        s.submit(r).unwrap();
    }
    let mut resps = s.run_until_drained().unwrap();
    resps.sort_by_key(|r| r.id);
    let tokens = resps.into_iter().map(|r| r.tokens).collect();
    reconcile_scheduler(sc.name, &mut s);
    (tokens, s.metrics().traffic_snapshot())
}

/// Adaptive-vs-static plan selection on the bundled scenarios, gated on
/// the deterministic modeled-cost counters (never wall time):
///
/// * token outputs are bit-identical across every plan choice;
/// * the adaptive planner's modeled cycles are ≤ every static plan's
///   on every scenario (so it is never worse than the best static);
/// * its prediction error on the mock stays within 2×;
/// * and it demonstrably selects different plans for the
///   prefill-heavy and decode-heavy scenarios.
///
/// Writes `BENCH_planner.json` with the counter-based speedup ratios.
fn planner_gate() {
    println!("\n== adaptive plan selection (mock engine, modeled-cost counters) ==");
    let mut scenarios_json = JsonValue::Arr(vec![]);
    let mut dominant: Vec<(String, String)> = Vec::new();
    for sc in ServeScenario::bundled() {
        let (adaptive_tokens, adaptive) =
            planner_run(&sc, Planner::with_dwell(PlanSpec::Adaptive, 1));
        let mut statics = Vec::new();
        for choice in PlanChoice::candidates() {
            let (tokens, snap) = planner_run(&sc, Planner::new(PlanSpec::Static(choice)));
            assert_eq!(
                tokens, adaptive_tokens,
                "{}: tokens diverged under static:{}",
                sc.name,
                choice.name()
            );
            statics.push((choice, snap));
        }

        // The counter gate: adaptive ≤ every static plan.
        let mut best_static = u64::MAX;
        let mut statics_json = JsonValue::Arr(vec![]);
        for (choice, snap) in &statics {
            best_static = best_static.min(snap.modeled_cycles);
            assert!(
                adaptive.modeled_cycles <= snap.modeled_cycles,
                "{}: adaptive {} cycles worse than static:{} {}",
                sc.name,
                adaptive.modeled_cycles,
                choice.name(),
                snap.modeled_cycles
            );
            let mut o = JsonValue::obj();
            o.set("plan", choice.name())
                .set("modeled_cycles", snap.modeled_cycles)
                .set("modeled_bytes", snap.modeled_bytes)
                .set(
                    "speedup_vs_adaptive",
                    (snap.modeled_cycles as f64 / adaptive.modeled_cycles.max(1) as f64 * 1e3)
                        .round()
                        / 1e3,
                );
            statics_json.push(o);
        }

        // Predictor sanity: the mock behaves within 2× of prediction.
        let err = adaptive.prediction_error();
        assert!(
            (0.5..=2.0).contains(&err),
            "{}: predictor error {err:.3} outside 2x",
            sc.name
        );

        let dom = adaptive
            .dominant_plan()
            .map(|(c, _)| c.name())
            .unwrap_or_default();
        println!(
            "  {:<14} adaptive={:<10} cycles (best static {:<10}) plans={} switches={} err={:.2}x",
            sc.name,
            adaptive.modeled_cycles,
            best_static,
            dom,
            adaptive.plan_switches,
            err
        );
        dominant.push((sc.name.to_string(), dom.clone()));

        let mut o = JsonValue::obj();
        o.set("scenario", sc.name)
            .set("adaptive_modeled_cycles", adaptive.modeled_cycles)
            .set("adaptive_modeled_bytes", adaptive.modeled_bytes)
            .set("adaptive_plan_switches", adaptive.plan_switches)
            .set("adaptive_dominant_plan", dom.as_str())
            .set("best_static_modeled_cycles", best_static)
            .set("prediction_error", (err * 1e3).round() / 1e3)
            .set("statics", statics_json)
            .set("pass", adaptive.modeled_cycles <= best_static);
        scenarios_json.push(o);
    }

    // The phase flip: prefill-heavy and decode-heavy pick differently.
    let by_name = |n: &str| {
        dominant
            .iter()
            .find(|(s, _)| s == n)
            .map(|(_, d)| d.clone())
            .expect("bundled scenario ran")
    };
    let (pre, dec) = (by_name("prefill_heavy"), by_name("decode_heavy"));
    assert_ne!(
        pre, dec,
        "adaptive planner failed to switch plans between prefill-heavy and decode-heavy"
    );

    let mut gate = JsonValue::obj();
    gate.set("adaptive_never_worse_than_best_static", true)
        .set("prefill_heavy_plan", pre.as_str())
        .set("decode_heavy_plan", dec.as_str())
        .set("phase_flip", true)
        .set("pass", true);
    let mut doc = JsonValue::obj();
    doc.set("bench", "planner")
        .set("scenarios", scenarios_json)
        .set("gate", gate);
    std::fs::write("BENCH_planner.json", doc.to_string())
        .expect("writing BENCH_planner.json");
    println!("wrote BENCH_planner.json (planner gate: PASS)");
}

/// One chunk-heavy scheduler run with an explicit engine capability
/// report. Returns `(tokens, traffic snapshot, ticks, chunk ticks,
/// prefill tokens)`.
fn engine_api_run(caps: EngineCaps) -> (Vec<Vec<i32>>, TrafficSnapshot, u64, u64, u64) {
    let sc = ServeScenario::chunk_heavy();
    let vocab = MockEngine::new().manifest().vocab;
    let mut s = Scheduler::new(MockEngine::with_caps(caps), sc.policy.clone());
    for r in sc.requests(vocab) {
        s.submit(r).unwrap();
    }
    let mut resps = s.run_until_drained().unwrap();
    resps.sort_by_key(|r| r.id);
    let tokens = resps.into_iter().map(|r| r.tokens).collect();
    reconcile_scheduler("engine_api", &mut s);
    let met = s.metrics();
    (tokens, met.traffic_snapshot(), met.ticks, met.prefill_batches, met.prefill_tokens)
}

/// The engine-API gate: the *same* mock engine serves the chunk-heavy
/// scenario twice, with its capability report flipped between
/// `varlen_kernel: true` (fused launch) and `false` (the default
/// compiled-primitive decomposition), gated on deterministic counters
/// (never wall time):
///
/// * token outputs are bit-identical — capability negotiation changes
///   nothing observable;
/// * the fused path makes **exactly 1 device call per tick** and
///   stages zero state bytes;
/// * the decomposition pays at least its lockstep floor — a
///   chunk-carrying tick's lockstep scan runs `max(chunk)` positions,
///   and with at most `max_chunk_rows` chunks per tick that is ≥
///   `⌈chunk tokens of the tick / max_chunk_rows⌉`, so summed over the
///   run the scan calls alone are ≥ `⌈prefill_tokens /
///   max_chunk_rows⌉`, plus one call for every chunk-free tick — and
///   nonzero staging traffic.
///
/// Writes `BENCH_engine_api.json` with the call/byte ratios.
fn engine_api_gate() {
    println!("\n== engine API: caps-negotiated varlen launch vs decomposition ==");
    let (fused_tokens, fused, fused_ticks, fused_chunk_ticks, _) =
        engine_api_run(EngineCaps::full());
    let (decomp_tokens, decomp, decomp_ticks, chunk_ticks, prefill_tokens) =
        engine_api_run(EngineCaps { varlen_kernel: false, ..EngineCaps::full() });
    let fused_staged = fused.bytes_gathered + fused.bytes_scattered;
    let decomp_staged = decomp.bytes_gathered + decomp.bytes_scattered;
    for (name, t, calls, staged) in [
        ("fused(varlen_kernel)", fused_ticks, fused.device_calls, fused_staged),
        ("decomposition", decomp_ticks, decomp.device_calls, decomp_staged),
    ] {
        println!("  {name:<22} ticks={t:<4} device_calls={calls:<5} staged_bytes={staged}");
    }

    // Gate 1 (equivalence): the caps toggle changes no output and no
    // schedule.
    assert_eq!(fused_tokens, decomp_tokens, "caps toggle changed tokens");
    assert_eq!(fused_ticks, decomp_ticks, "caps toggle changed the schedule");

    // Gate 2 (the fused contract): 1 device call per tick, zero staged
    // state bytes, zero padding.
    assert_eq!(fused.device_calls, fused_ticks, "fused path must launch once per tick");
    assert_eq!(fused_staged, 0, "fused path must stage nothing");
    assert_eq!(fused.padded_rows, 0);

    // Gate 3 (the decomposition's lockstep cost): a chunk tick's scan
    // runs max(chunk) positions ≥ ⌈its chunk tokens / max_chunk_rows⌉,
    // so scan calls over the run ≥ ⌈prefill_tokens / max_chunk_rows⌉;
    // chunk-free ticks cost ≥ 1 call each. Provable from the counters
    // alone, and far above the fused path's 1-per-tick.
    let r = ServeScenario::chunk_heavy().policy.max_chunk_rows as u64;
    let lockstep_floor = (decomp_ticks - chunk_ticks) + (prefill_tokens + r - 1) / r;
    assert!(chunk_ticks > 0, "chunk-heavy scenario must have chunk ticks");
    assert!(
        decomp.device_calls >= lockstep_floor,
        "decomposition paid {} device calls < lockstep floor {lockstep_floor}",
        decomp.device_calls
    );
    assert!(
        lockstep_floor > 2 * fused.device_calls,
        "scenario must make the lockstep floor dominate the fused cost \
         ({lockstep_floor} vs {})",
        fused.device_calls
    );
    assert!(decomp_staged > 0, "decomposition must stage state bytes");

    let call_ratio = decomp.device_calls as f64 / fused.device_calls.max(1) as f64;
    let mut gate = JsonValue::obj();
    gate.set("tokens_identical", true)
        .set("fused_calls_per_tick", 1u64)
        .set("decomp_device_calls", decomp.device_calls)
        .set("lockstep_floor", lockstep_floor)
        .set("device_call_ratio", (call_ratio * 1e3).round() / 1e3)
        .set("fused_staged_bytes", fused_staged)
        .set("decomp_staged_bytes", decomp_staged)
        .set("pass", true);
    let mut runs = JsonValue::Arr(vec![]);
    for (name, ticks, chunk, t) in [
        ("fused", fused_ticks, fused_chunk_ticks, &fused),
        ("decomposition", decomp_ticks, chunk_ticks, &decomp),
    ] {
        let mut j = JsonValue::obj();
        j.set("name", name)
            .set("ticks", ticks)
            .set("chunk_ticks", chunk)
            .set("device_calls", t.device_calls)
            .set("bytes_gathered", t.bytes_gathered)
            .set("bytes_scattered", t.bytes_scattered)
            .set("padded_rows", t.padded_rows);
        runs.push(j);
    }
    let mut doc = JsonValue::obj();
    doc.set("bench", "engine_api").set("runs", runs).set("gate", gate);
    std::fs::write("BENCH_engine_api.json", doc.to_string())
        .expect("writing BENCH_engine_api.json");
    println!("wrote BENCH_engine_api.json (engine API gate: PASS)");
}

/// How a hot-skew run treats the requests stranded on the hot worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SkewMode {
    /// Pre-sharding behaviour: requests stay pinned where they landed.
    Pinned,
    /// Sharded arena: migrate part of the hot decode set by moving
    /// resident state rows.
    Migrate,
    /// Migration realized as the re-prefill fallback (the cost the
    /// state move eliminates, priced on the same counters).
    Reprefill,
}

struct SkewOutcome {
    name: &'static str,
    tokens: Vec<Vec<i32>>,
    hot_ticks: u64,
    cold_ticks: u64,
    migrations: u64,
    bytes_migrated: u64,
    reprefills_avoided: u64,
    reprefill_tokens: u64,
    bytes_per_seq: u64,
    /// Checked (and meaningful) only for the Migrate run — a state
    /// move must leave the global gauge invariant. `None` for modes
    /// that never measured it.
    gauge_conserved: Option<bool>,
}

/// One deterministic hot-skew run on a two-shard scheduler pair: six
/// long-decode requests pinned hot, one cold. At a fixed tick (all six
/// deterministically decoding) three of the hot requests move to the
/// cold shard — by state move, by re-prefill, or not at all. Pure
/// single-threaded scheduling, so every counter is workload-
/// deterministic: same run, same numbers, every time.
fn sharded_skew_run(mode: SkewMode) -> SkewOutcome {
    let sc = ServeScenario::sharded_skew();
    let vocab = MockEngine::new().manifest().vocab;
    let mut hot = Scheduler::with_path(MockEngine::new(), sc.policy.clone(), StatePath::Resident);
    hot.set_shard(0);
    let mut cold = Scheduler::with_path(MockEngine::new(), sc.policy.clone(), StatePath::Resident);
    cold.set_shard(1);
    let bytes_per_seq = hot.state_arena().bytes_per_seq() as u64;
    for r in sc.requests(vocab) {
        if ServeScenario::SHARDED_HOT_IDS.contains(&r.id) {
            hot.submit(r).unwrap();
        } else {
            cold.submit(r).unwrap();
        }
    }

    // 16-token prompts × 6 on a 16-token budget: prefill interleaves
    // with early decode and the whole hot set is decoding well before
    // tick 14 (the scheduler asserts it via detach's running check).
    const MIGRATE_TICK: u32 = 14;
    let mut responses: Vec<mambalaya::coordinator::Response> = Vec::new();
    let mut gauge_conserved: Option<bool> = None;
    let mut tick = 0u32;
    loop {
        let (a, pa) = hot.tick().unwrap();
        let (b, pb) = cold.tick().unwrap();
        responses.extend(a);
        responses.extend(b);
        tick += 1;
        assert!(tick < 10_000, "skew scenario did not drain");
        if tick == MIGRATE_TICK && mode != SkewMode::Pinned {
            for seq in [1u64, 2, 3] {
                let before =
                    hot.state_arena().resident_bytes() + cold.state_arena().resident_bytes();
                let p = hot.detach(seq).expect("hot request is decoding at the migrate tick");
                assert!(p.decode_phase());
                match mode {
                    SkewMode::Migrate => {
                        cold.attach(p).expect("well-formed packet attaches");
                        let after = hot.state_arena().resident_bytes()
                            + cold.state_arena().resident_bytes();
                        gauge_conserved =
                            Some(gauge_conserved.unwrap_or(true) && after == before);
                    }
                    SkewMode::Reprefill => cold.attach_reprefill(p),
                    SkewMode::Pinned => unreachable!(),
                }
            }
        }
        if !pa && !pb && hot.pending() + cold.pending() == 0 {
            break;
        }
    }
    responses.sort_by_key(|r| r.id);
    let tokens = responses.iter().map(|r| r.tokens.clone()).collect();
    // Reconciliation property across the shard pair: a migrated span
    // starts hot and terminates cold, and `migrations` counts attaches
    // only, so the check must run on the combined trace against the
    // accumulated snapshot — per-shard it would be lopsided by design.
    assert_eq!(
        hot.trace_dropped() + cold.trace_dropped(),
        0,
        "sharding: trace ring overflowed"
    );
    let mut trace = hot.take_trace();
    trace.extend(cold.take_trace());
    let mut combined = hot.metrics().traffic_snapshot();
    combined.accumulate(&cold.metrics().traffic_snapshot());
    reconcile(&trace, &combined)
        .unwrap_or_else(|e| panic!("sharding({mode:?}): reconciliation failed: {e}"));
    SkewOutcome {
        name: match mode {
            SkewMode::Pinned => "pinned",
            SkewMode::Migrate => "migrate",
            SkewMode::Reprefill => "reprefill",
        },
        tokens,
        hot_ticks: hot.metrics().ticks,
        cold_ticks: cold.metrics().ticks,
        migrations: hot.metrics().migrations + cold.metrics().migrations,
        bytes_migrated: hot.metrics().bytes_migrated + cold.metrics().bytes_migrated,
        reprefills_avoided: hot.metrics().reprefills_avoided
            + cold.metrics().reprefills_avoided,
        reprefill_tokens: hot.metrics().reprefill_tokens + cold.metrics().reprefill_tokens,
        bytes_per_seq,
        gauge_conserved,
    }
}

/// Hot-worker skew with and without migration, gated on deterministic
/// counters (never wall time):
///
/// * token outputs are bit-identical across pinned / migrate /
///   re-prefill — moving state changes nothing observable;
/// * a migration moves exactly `state_bytes_per_seq` and conserves the
///   global resident gauge, with `reprefills_avoided ≥ 1`;
/// * the migrated traffic beats the re-prefill fallback by ≥ 5× —
///   re-prefilling replays `reprefill_tokens` state updates (one
///   `state_bytes_per_seq` write per token) where the move pays one
///   transfer per request.
///
/// Writes `BENCH_sharding.json`.
fn sharding_gate() {
    println!("\n== sharded state arena: hot-skew migration (deterministic counters) ==");
    let runs = [
        sharded_skew_run(SkewMode::Pinned),
        sharded_skew_run(SkewMode::Migrate),
        sharded_skew_run(SkewMode::Reprefill),
    ];
    for o in &runs {
        println!(
            "  {:<10} hot_ticks={:<4} cold_ticks={:<4} migrations={} migrated={}B \
             reprefills_avoided={} reprefill_tokens={}",
            o.name,
            o.hot_ticks,
            o.cold_ticks,
            o.migrations,
            o.bytes_migrated,
            o.reprefills_avoided,
            o.reprefill_tokens,
        );
    }
    let (pinned, migrate, reprefill) = (&runs[0], &runs[1], &runs[2]);

    // Gate 1 (conformance): migration — either realization — changes
    // no output.
    assert_eq!(pinned.tokens, migrate.tokens, "state move changed tokens");
    assert_eq!(pinned.tokens, reprefill.tokens, "re-prefill fallback changed tokens");

    // Gate 2 (conservation): three decode-phase moves, each exactly one
    // state payload, gauge conserved at every move.
    assert_eq!(migrate.migrations, 3);
    assert_eq!(migrate.bytes_migrated, 3 * migrate.bytes_per_seq);
    assert!(migrate.reprefills_avoided >= 1);
    assert_eq!(migrate.reprefills_avoided, 3);
    assert_eq!(
        migrate.gauge_conserved,
        Some(true),
        "resident gauge not conserved across migration"
    );
    assert_eq!(migrate.reprefill_tokens, 0);

    // Gate 3 (the sharding acceptance bar): migrated traffic beats the
    // re-prefill fallback by ≥ 5× on the deterministic counters. Each
    // replayed token is one state update — one state_bytes_per_seq
    // write the device cannot skip — so the fallback's byte cost is
    // reprefill_tokens × state_bytes_per_seq vs one payload per move.
    assert_eq!(reprefill.bytes_migrated, 0);
    assert!(reprefill.reprefill_tokens > 0);
    let reprefill_bytes = reprefill.reprefill_tokens * reprefill.bytes_per_seq;
    assert!(
        reprefill_bytes >= 5 * migrate.bytes_migrated,
        "sharding gate failed: reprefill fallback {reprefill_bytes}B < 5x migrated {}B",
        migrate.bytes_migrated
    );

    let mut arr = JsonValue::Arr(vec![]);
    for o in &runs {
        let mut j = JsonValue::obj();
        j.set("name", o.name)
            .set("hot_ticks", o.hot_ticks)
            .set("cold_ticks", o.cold_ticks)
            .set("migrations", o.migrations)
            .set("bytes_migrated", o.bytes_migrated)
            .set("reprefills_avoided", o.reprefills_avoided)
            .set("reprefill_tokens", o.reprefill_tokens)
            .set("state_bytes_per_seq", o.bytes_per_seq);
        // Only the migrate run measures gauge conservation; don't
        // claim it for runs that never checked.
        if let Some(conserved) = o.gauge_conserved {
            j.set("resident_gauge_conserved", conserved);
        }
        arr.push(j);
    }
    let mut gate = JsonValue::obj();
    gate.set("tokens_identical", true)
        .set("bytes_migrated", migrate.bytes_migrated)
        .set("reprefills_avoided", migrate.reprefills_avoided)
        .set("resident_gauge_conserved", migrate.gauge_conserved == Some(true))
        .set("reprefill_fallback_bytes", reprefill_bytes)
        .set(
            "migration_traffic_advantage",
            ((reprefill_bytes as f64 / migrate.bytes_migrated.max(1) as f64) * 1e3).round()
                / 1e3,
        )
        .set("advantage_min", 5u64)
        .set("pass", true);
    let mut doc = JsonValue::obj();
    doc.set("bench", "sharding").set("runs", arr).set("gate", gate);
    std::fs::write("BENCH_sharding.json", doc.to_string())
        .expect("writing BENCH_sharding.json");
    println!("wrote BENCH_sharding.json (sharding gate: PASS)");
}

/// Session snapshot cache, gated on deterministic counters (never wall
/// time):
///
/// * multi-turn: each follow-up turn prefills *only* its new tokens —
///   the shared history is restored by one `state_bytes_per_seq` copy
///   (`snapshot_bytes_restored`) and lands in `prefill_tokens_skipped`;
/// * the snapshot-attach path is token-identical to a full re-prefill
///   of the same turn-2 prompts on a session-less scheduler, and the
///   skipped traffic beats the fallback's replay bytes by ≥ 5×;
/// * best-of-N: N decode candidates are served from exactly one
///   prefill via copy-on-write forks — `snapshot_forks == N`, zero new
///   cached bytes, each candidate prefilling exactly its 1 new token.
///
/// Writes `BENCH_snapshot.json`.
fn snapshot_gate() {
    println!("\n== session snapshot cache: multi-turn skip + best-of-N fork ==");
    let vocab = MockEngine::new().manifest().vocab;

    // ---- multi-turn: follow-up turns prefill only their new tokens ----
    let sc = ServeScenario::multi_turn();
    let turn1 = sc.requests(vocab);
    let mut s = Scheduler::with_path(MockEngine::new(), sc.policy.clone(), StatePath::Resident);
    for r in &turn1 {
        // Session id = conversation id = turn-1 request id.
        s.submit_session(r.clone(), Some(r.id)).unwrap();
    }
    let mut t1 = s.run_until_drained().unwrap();
    t1.sort_by_key(|r| r.id);
    let bytes_per_seq = s.state_arena().bytes_per_seq() as u64;
    let prefill_turn1 = s.metrics().prefill_tokens;
    assert_eq!(prefill_turn1, 4 * 24, "turn 1 pays the full prompts");
    assert_eq!(s.metrics().snapshots_stored, ServeScenario::MULTI_TURN_SESSIONS);

    let fresh = ServeScenario::MULTI_TURN_NEW_TOKENS;
    let mut expected_new = 0u64;
    let mut expected_skip = 0u64;
    let turn2: Vec<Request> = turn1
        .iter()
        .zip(&t1)
        .map(|(r, resp)| {
            expected_skip += ServeScenario::session_history(&r.prompt, &resp.tokens).len() as u64;
            expected_new += (fresh + 1) as u64; // fresh tokens + the un-fed last reply token
            Request {
                id: 1000 + r.id,
                prompt: ServeScenario::follow_up_prompt(&r.prompt, &resp.tokens, fresh, vocab),
                max_new_tokens: 8,
            }
        })
        .collect();
    for (r2, r1) in turn2.iter().zip(&turn1) {
        s.submit_session(r2.clone(), Some(r1.id)).unwrap();
    }
    let mut t2 = s.run_until_drained().unwrap();
    t2.sort_by_key(|r| r.id);
    let prefill_turn2 = s.metrics().prefill_tokens - prefill_turn1;
    // Reconciliation property over both turns, snapshot hits included:
    // Σ SnapshotHit.tokens_skipped must equal the skip counter exactly.
    reconcile_scheduler("snapshot(multi_turn)", &mut s);
    let met = s.metrics();
    println!(
        "  multi_turn  turn2_prefill={prefill_turn2} skipped={} hits={} restored={}B",
        met.prefill_tokens_skipped, met.snapshot_hits, met.snapshot_bytes_restored,
    );

    // Gate 1 (the skip): turn 2 prefills exactly the new tokens; every
    // history token is skipped and counted.
    assert_eq!(prefill_turn2, expected_new, "turn 2 prefilled more than its new tokens");
    assert_eq!(met.snapshot_hits, ServeScenario::MULTI_TURN_SESSIONS);
    assert_eq!(met.prefill_tokens_skipped, expected_skip);
    assert_eq!(
        met.snapshot_bytes_restored,
        ServeScenario::MULTI_TURN_SESSIONS * bytes_per_seq,
        "each hit restores exactly one state payload"
    );

    // Gate 2 (conformance): a session-less scheduler re-prefilling the
    // full turn-2 prompts produces bit-identical tokens — and pays for
    // every skipped token.
    let mut base = Scheduler::with_path(MockEngine::new(), sc.policy.clone(), StatePath::Resident);
    for r in &turn2 {
        base.submit(r.clone()).unwrap();
    }
    let mut tb = base.run_until_drained().unwrap();
    tb.sort_by_key(|r| r.id);
    let t2_tokens: Vec<Vec<i32>> = t2.iter().map(|r| r.tokens.clone()).collect();
    let tb_tokens: Vec<Vec<i32>> = tb.iter().map(|r| r.tokens.clone()).collect();
    assert_eq!(t2_tokens, tb_tokens, "snapshot attach changed tokens");
    let full_prefill = base.metrics().prefill_tokens;
    assert_eq!(full_prefill, expected_new + expected_skip);

    // Gate 3 (the acceptance bar): each skipped token is one state
    // update the fallback cannot avoid — one state_bytes_per_seq write
    // — vs one payload copy per hit.
    let fallback_bytes = expected_skip * bytes_per_seq;
    let restored = met.snapshot_bytes_restored;
    assert!(
        fallback_bytes >= 5 * restored,
        "snapshot gate failed: re-prefill fallback {fallback_bytes}B < 5x restored {restored}B"
    );

    // ---- best-of-N: N decodes from one prefill via CoW fork ----
    let sc_n = ServeScenario::best_of_n();
    let parent_req = sc_n.requests(vocab).remove(0);
    let parent_session = 7u64;
    let n = ServeScenario::BEST_OF_N;
    let mut f = Scheduler::with_path(MockEngine::new(), sc_n.policy.clone(), StatePath::Resident);
    f.submit_session(parent_req.clone(), Some(parent_session)).unwrap();
    let shared = f.run_until_drained().unwrap().remove(0);
    assert_eq!(shared.tokens.len(), 1);
    let prefill_shared = f.metrics().prefill_tokens;
    assert_eq!(prefill_shared, parent_req.prompt.len() as u64);

    let cached_before = f.snapshot_cache().resident_bytes();
    for i in 0..n as u64 {
        assert!(f.fork_session(parent_session, 100 + i), "fork {i} failed");
    }
    assert_eq!(
        f.snapshot_cache().resident_bytes(),
        cached_before,
        "CoW forks must add zero cached bytes"
    );
    assert_eq!(f.metrics().snapshot_forks, n as u64);

    let children: Vec<Request> = (0..n as u64)
        .map(|i| {
            let mut p = parent_req.prompt.clone();
            p.push(shared.tokens[0]); // the sampled token joins the prompt
            Request { id: 10 + i, prompt: p, max_new_tokens: 8 }
        })
        .collect();
    for (i, r) in children.iter().enumerate() {
        f.submit_session(r.clone(), Some(100 + i as u64)).unwrap();
    }
    let mut outs = f.run_until_drained().unwrap();
    outs.sort_by_key(|r| r.id);
    let prefill_children = f.metrics().prefill_tokens - prefill_shared;
    println!(
        "  best_of_n   candidates={n} candidate_prefill={prefill_children} forks={}",
        f.metrics().snapshot_forks,
    );
    assert_eq!(
        prefill_children, n as u64,
        "each candidate must prefill exactly its 1 new token"
    );
    assert_eq!(f.metrics().snapshot_hits, n as u64);
    reconcile_scheduler("snapshot(best_of_n)", &mut f);

    // Conformance: a candidate decoded from the fork matches a full
    // re-prefill of the same prompt.
    let mut base_n =
        Scheduler::with_path(MockEngine::new(), sc_n.policy.clone(), StatePath::Resident);
    base_n.submit(children[0].clone()).unwrap();
    let solo = base_n.run_until_drained().unwrap().remove(0);
    for o in &outs {
        assert_eq!(o.tokens, solo.tokens, "forked candidate diverged from full re-prefill");
    }

    let mut arr = JsonValue::Arr(vec![]);
    let mut mt = JsonValue::obj();
    mt.set("name", "multi_turn")
        .set("sessions", ServeScenario::MULTI_TURN_SESSIONS)
        .set("turn1_prefill_tokens", prefill_turn1)
        .set("turn2_prefill_tokens", prefill_turn2)
        .set("prefill_tokens_skipped", met.prefill_tokens_skipped)
        .set("snapshot_hits", met.snapshot_hits)
        .set("snapshot_bytes_restored", restored)
        .set("reprefill_fallback_bytes", fallback_bytes)
        .set("full_reprefill_tokens", full_prefill)
        .set("state_bytes_per_seq", bytes_per_seq);
    arr.push(mt);
    let mut bn = JsonValue::obj();
    bn.set("name", "best_of_n")
        .set("candidates", n as u64)
        .set("shared_prefill_tokens", prefill_shared)
        .set("candidate_prefill_tokens", prefill_children)
        .set("snapshot_forks", n as u64)
        .set("fork_cached_bytes_added", 0u64);
    arr.push(bn);
    let mut gate = JsonValue::obj();
    gate.set("tokens_identical", true)
        .set("turn2_prefill_is_new_tokens_only", true)
        .set("prefill_tokens_skipped", met.prefill_tokens_skipped)
        .set("snapshot_bytes_restored", restored)
        .set("reprefill_fallback_bytes", fallback_bytes)
        .set(
            "snapshot_traffic_advantage",
            ((fallback_bytes as f64 / restored.max(1) as f64) * 1e3).round() / 1e3,
        )
        .set("advantage_min", 5u64)
        .set("best_of_n_single_prefill", true)
        .set("pass", true);
    let mut doc = JsonValue::obj();
    doc.set("bench", "snapshot").set("runs", arr).set("gate", gate);
    std::fs::write("BENCH_snapshot.json", doc.to_string())
        .expect("writing BENCH_snapshot.json");
    println!("wrote BENCH_snapshot.json (snapshot gate: PASS)");
}

/// One fault-recovery run of the `fault_storm` population. A donor
/// shard builds all eight requests to steady-state decode, the whole
/// population migrates onto a faulty worker whose serialized policy
/// (`token_budget: 1`) launches exactly one row per tick, and the
/// injected `nth:3` launch fault poisons that scheduler with exactly
/// one suspect row. [`Scheduler::salvage`] then exports the wreck and
/// a healthy shard finishes the job — either resuming the seven
/// untouched rows from their salvaged state (`salvage: true`) or
/// replaying every row's history (`salvage: false`, the
/// reprefill-everything floor). Pure single-threaded scheduling, so
/// every counter is workload-deterministic.
struct SalvageOutcome {
    name: &'static str,
    tokens: Vec<Vec<i32>>,
    suspects: usize,
    state_packets: u64,
    migrations: u64,
    bytes_migrated: u64,
    replayed_tokens: u64,
    bytes_per_seq: u64,
    faults_injected: u64,
}

fn salvage_run(salvage: bool) -> SalvageOutcome {
    let sc = ServeScenario::fault_storm();
    let vocab = MockEngine::new().manifest().vocab;
    let n = ServeScenario::FAULT_STORM_REQUESTS;

    // Donor shard: twelve ticks leave all eight requests deep in
    // decode (6-token prompts fully prefilled, nobody near max_new).
    let mut donor =
        Scheduler::with_path(MockEngine::new(), sc.policy.clone(), StatePath::Resident);
    donor.set_shard(0);
    for r in sc.requests(vocab) {
        donor.submit(r).unwrap();
    }
    let mut responses = Vec::new();
    for _ in 0..12 {
        let (done, _) = donor.tick().unwrap();
        responses.extend(done);
    }
    assert!(responses.is_empty(), "fault_storm population completed before the fault");

    // Faulty shard: token_budget 1 serializes decode, so the third
    // launch — the one the plan fails — carries exactly one row.
    let tight = BatchPolicy { token_budget: 1, max_chunk_rows: 1, ..sc.policy.clone() };
    let inj = FaultInjector::new(FaultPlan::parse("nth:3").unwrap());
    let mut faulty = Scheduler::with_path(
        inj.wrap(MockEngine::new()).unwrap(),
        tight,
        StatePath::Resident,
    );
    faulty.set_shard(1);
    for seq in 0..n {
        let p = donor.detach(seq).expect("donor row is decoding after 12 ticks");
        faulty.attach(p).expect("well-formed packet attaches");
    }

    let mut fault = None;
    for _ in 0..8 {
        match faulty.tick() {
            Ok((done, _)) => responses.extend(done),
            Err(e) => {
                fault = Some(e);
                break;
            }
        }
    }
    let fault = fault.expect("nth:3 fires within eight serialized ticks");
    assert!(
        fault.to_string().contains("injected launch fault"),
        "unexpected failure: {fault:#}"
    );
    assert!(faulty.poisoned());
    let suspects = faulty.suspect_rows().len();
    // Salvage consumes the scheduler, so its lifecycle evidence — the
    // trace (including the Fault record) and the counters it must
    // reconcile against — is captured before the wreck is exported.
    assert_eq!(faulty.trace_dropped(), 0, "resilience: trace ring overflowed");
    let faulty_trace = faulty.take_trace();
    let faulty_snap = faulty.metrics().traffic_snapshot();
    let packets = faulty.salvage();
    assert_eq!(packets.len(), n as usize, "salvage exports every in-flight row");

    // Recovery shard: attach what the fault never touched, replay the
    // rest — or replay everything, which is what salvage replaces.
    let mut healthy =
        Scheduler::with_path(MockEngine::new(), sc.policy.clone(), StatePath::Resident);
    healthy.set_shard(2);
    let mut state_packets = 0u64;
    for p in packets {
        if salvage && p.state_bytes() > 0 {
            state_packets += 1;
            healthy.attach(p).expect("salvaged state re-attaches");
        } else {
            healthy.attach_reprefill(p);
        }
    }
    responses.extend(healthy.run_until_drained().unwrap());
    responses.sort_by_key(|r| r.id);
    assert_eq!(responses.len(), n as usize);
    // Reconciliation property across the whole fault story: every span
    // submits on the donor, migrates through the faulty shard (whose
    // trace carries the Fault record), and terminates exactly once on
    // the recovery shard — and the summed counters balance the events.
    assert!(
        faulty_trace.iter().any(|r| matches!(r.event, TraceEvent::Fault)),
        "faulty shard left no Fault record"
    );
    let mut trace = donor.take_trace();
    assert_eq!(donor.trace_dropped() + healthy.trace_dropped(), 0);
    trace.extend(faulty_trace);
    trace.extend(healthy.take_trace());
    let mut combined = donor.metrics().traffic_snapshot();
    combined.accumulate(&faulty_snap);
    combined.accumulate(&healthy.metrics().traffic_snapshot());
    reconcile(&trace, &combined).unwrap_or_else(|e| {
        panic!(
            "resilience({}): reconciliation failed: {e}",
            if salvage { "salvage" } else { "reprefill_everything" }
        )
    });
    let met = healthy.metrics();
    SalvageOutcome {
        name: if salvage { "salvage" } else { "reprefill_everything" },
        tokens: responses.iter().map(|r| r.tokens.clone()).collect(),
        suspects,
        state_packets,
        migrations: met.migrations,
        bytes_migrated: met.bytes_migrated,
        replayed_tokens: met.reprefill_tokens,
        bytes_per_seq: healthy.state_arena().bytes_per_seq() as u64,
        faults_injected: inj.faults_injected(),
    }
}

/// Pump server supervision while waiting on a response sink. A worker
/// death is only observed at the next [`Server::supervise`], so a bare
/// blocking `recv` could wait on a re-route that nobody has issued
/// yet; a sink that disconnects without a terminal message is exactly
/// the dropped-sink bug the gate exists to catch, so it panics.
fn recv_supervised(server: &mut Server, rx: &Receiver<Response>) -> Response {
    let deadline = Instant::now() + Duration::from_secs(30);
    while Instant::now() < deadline {
        server.supervise();
        match rx.recv_timeout(Duration::from_millis(2)) {
            Ok(r) => return r,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => {
                panic!("sink dropped without a terminal response")
            }
        }
    }
    panic!("no response within 30s of supervised pumping");
}

/// Fault-injected engine failures, gated on deterministic counters
/// (never wall time):
///
/// * recoverable requests are **bit-identical** to the fault-free
///   baseline, whether they resume from salvaged state or replay
///   their history;
/// * salvage replays only the suspect row the failing launch touched
///   — ≥ 5× fewer replayed tokens than the reprefill-everything
///   floor — and moves exactly one state payload per untouched row;
/// * the threaded server respawns a fail-once worker within its
///   restart cap and completes every request bit-identically;
/// * a permanent fault ends with **exactly one terminal message per
///   sink** — an error `Response`, never a dropped channel.
///
/// Writes `BENCH_resilience.json`.
fn resilience_gate() {
    println!("\n== fault-injected failures: salvage vs reprefill, supervised respawn ==");
    let n = ServeScenario::FAULT_STORM_REQUESTS;

    // ---- fault-free baseline: the bit-identity reference ----
    let sc = ServeScenario::fault_storm();
    let vocab = MockEngine::new().manifest().vocab;
    let mut base =
        Scheduler::with_path(MockEngine::new(), sc.policy.clone(), StatePath::Resident);
    for r in sc.requests(vocab) {
        base.submit(r).unwrap();
    }
    let mut base_resps = base.run_until_drained().unwrap();
    base_resps.sort_by_key(|r| r.id);
    let base_tokens: Vec<Vec<i32>> = base_resps.iter().map(|r| r.tokens.clone()).collect();

    // ---- scheduler-level: salvage vs reprefill-everything ----
    let salv = salvage_run(true);
    let rep = salvage_run(false);
    for o in [&salv, &rep] {
        println!(
            "  {:<22} suspects={} state_packets={} migrated={}B replayed_tokens={} faults={}",
            o.name, o.suspects, o.state_packets, o.bytes_migrated, o.replayed_tokens,
            o.faults_injected,
        );
    }

    // Gate 1 (conformance): both recoveries change no output.
    assert_eq!(salv.tokens, base_tokens, "salvaged recovery changed tokens");
    assert_eq!(rep.tokens, base_tokens, "reprefill recovery changed tokens");

    // Gate 2 (conservation): the serialized fault touches exactly one
    // row; salvage moves exactly one state payload per untouched row
    // and replays only the suspect, the floor replays everything and
    // moves nothing.
    assert_eq!(salv.suspects, 1, "token_budget 1 must launch exactly one row");
    assert_eq!(salv.state_packets, n - 1);
    assert_eq!(salv.bytes_migrated, (n - 1) * salv.bytes_per_seq);
    assert_eq!(salv.migrations, n, "every salvaged row re-routes exactly once");
    assert!(salv.replayed_tokens > 0, "the suspect row must replay its history");
    assert_eq!(rep.state_packets, 0);
    assert_eq!(rep.bytes_migrated, 0);
    assert_eq!(salv.faults_injected, 1);
    assert_eq!(rep.faults_injected, 1);

    // Gate 3 (the resilience acceptance bar): salvage beats
    // reprefill-everything ≥ 5× on the replayed-token counters.
    assert!(
        rep.replayed_tokens >= 5 * salv.replayed_tokens,
        "resilience gate failed: reprefill-everything {} tokens < 5x salvage {}",
        rep.replayed_tokens,
        salv.replayed_tokens
    );

    // ---- threaded: fail-once worker respawns within the cap ----
    let reqs = sc.requests(vocab);
    let inj = FaultInjector::new(FaultPlan::parse("once:3").unwrap());
    let factory = {
        let inj = inj.clone();
        move || inj.wrap(MockEngine::new())
    };
    let mut server = Server::start(vec![factory], sc.policy.clone());
    let rxs: Vec<_> = reqs.iter().map(|r| server.submit(r.clone())).collect();
    let mut got: Vec<Response> =
        rxs.iter().map(|rx| recv_supervised(&mut server, rx)).collect();
    got.sort_by_key(|r| r.id);
    for (g, b) in got.iter().zip(&base_resps) {
        assert!(!g.is_error(), "recoverable request {} failed: {:?}", g.id, g.error);
        assert_eq!(g.tokens, b.tokens, "recovered tokens diverged from fault-free baseline");
    }
    for rx in &rxs {
        assert!(rx.try_recv().is_err(), "sink got a second message after its terminal one");
    }
    let recover = server.resilience();
    assert_eq!(recover.workers_down, 1);
    assert_eq!(recover.worker_restarts, 1, "fail-once must respawn within the cap");
    assert_eq!(recover.requests_failed, 0);
    assert!(
        recover.requests_salvaged + recover.requests_reprefilled_on_fault >= 1,
        "the death must have re-routed at least one in-flight request"
    );
    assert_eq!(inj.faults_injected(), 1);
    assert!(server.shard_map().has_live());
    // Server-level reconciliation across the death: the dead
    // incarnation's trace and counters rode the Down event into the
    // server totals, so the property holds even though a worker was
    // killed mid-flight.
    let events = server.trace();
    assert!(
        events.iter().any(|r| matches!(r.event, TraceEvent::Fault)),
        "dead worker's Fault record lost"
    );
    reconcile(&events, &server.traffic())
        .unwrap_or_else(|e| panic!("resilience(fail_once): reconciliation failed: {e}"));
    server.shutdown();
    println!(
        "  fail_once_recover      down={} restarts={} salvaged={} reprefilled={} failed={}",
        recover.workers_down,
        recover.worker_restarts,
        recover.requests_salvaged,
        recover.requests_reprefilled_on_fault,
        recover.requests_failed,
    );

    // ---- threaded: permanent fault drains to terminal errors ----
    let inj2 = FaultInjector::new(FaultPlan::parse("nth:2").unwrap());
    let factory2 = {
        let inj2 = inj2.clone();
        move || inj2.wrap(MockEngine::new())
    };
    let mut doomed = Server::start(vec![factory2], sc.policy.clone());
    doomed.set_max_restarts(1);
    doomed.set_max_replays(2);
    let rxs2: Vec<_> = reqs.iter().map(|r| doomed.submit(r.clone())).collect();
    let got2: Vec<Response> =
        rxs2.iter().map(|rx| recv_supervised(&mut doomed, rx)).collect();
    for g in &got2 {
        assert!(g.is_error(), "request {} survived a permanent fault", g.id);
        assert!(g.tokens.is_empty(), "terminal error must carry no tokens");
    }
    for rx in &rxs2 {
        assert!(rx.try_recv().is_err(), "sink got a second message after its terminal one");
    }
    let perm = doomed.resilience();
    assert_eq!(perm.requests_failed, n, "every request gets exactly one terminal error");
    assert_eq!(perm.workers_down, 2, "the original and its one replacement both die");
    assert_eq!(perm.worker_restarts, 1, "respawns stop at the restart cap");
    assert_eq!(inj2.faults_injected(), 2);
    assert!(!doomed.shard_map().has_live(), "the exhausted shard must be unroutable");
    // Reconciliation with zero completions: every span terminates in
    // exactly one router-recorded Failed event, never a Completed.
    reconcile(&doomed.trace(), &doomed.traffic())
        .unwrap_or_else(|e| panic!("resilience(permanent): reconciliation failed: {e}"));
    doomed.shutdown();
    println!(
        "  permanent_fault        down={} restarts={} failed={} faults={} (every sink terminal)",
        perm.workers_down,
        perm.worker_restarts,
        perm.requests_failed,
        inj2.faults_injected(),
    );

    // Machine-readable output for CI and trend tracking.
    let mut runs = JsonValue::Arr(vec![]);
    for o in [&salv, &rep] {
        let mut j = JsonValue::obj();
        j.set("name", o.name)
            .set("suspect_rows", o.suspects as u64)
            .set("state_packets", o.state_packets)
            .set("migrations", o.migrations)
            .set("bytes_migrated", o.bytes_migrated)
            .set("replayed_tokens", o.replayed_tokens)
            .set("state_bytes_per_seq", o.bytes_per_seq)
            .set("faults_injected", o.faults_injected);
        runs.push(j);
    }
    for (name, s, faults) in [
        ("fail_once_recover", &recover, 1u64),
        ("permanent_fault", &perm, 2u64),
    ] {
        let mut j = JsonValue::obj();
        j.set("name", name)
            .set("workers_down", s.workers_down)
            .set("worker_restarts", s.worker_restarts)
            .set("requests_salvaged", s.requests_salvaged)
            .set("requests_reprefilled_on_fault", s.requests_reprefilled_on_fault)
            .set("requests_failed", s.requests_failed)
            .set("faults_injected", faults);
        runs.push(j);
    }
    let advantage = rep.replayed_tokens as f64 / salv.replayed_tokens.max(1) as f64;
    let mut gate = JsonValue::obj();
    gate.set("tokens_identical", true)
        .set("salvage_replayed_tokens", salv.replayed_tokens)
        .set("reprefill_everything_replayed_tokens", rep.replayed_tokens)
        .set("salvage_replay_advantage", (advantage * 1e3).round() / 1e3)
        .set("advantage_min", 5u64)
        .set("bytes_migrated", salv.bytes_migrated)
        .set("respawn_within_cap", true)
        .set("zero_dropped_sinks", true)
        .set("terminal_error_per_failed_request", true)
        .set("pass", true);
    let mut doc = JsonValue::obj();
    doc.set("bench", "resilience").set("runs", runs).set("gate", gate);
    std::fs::write("BENCH_resilience.json", doc.to_string())
        .expect("writing BENCH_resilience.json");
    println!("wrote BENCH_resilience.json (resilience gate: PASS)");
}

/// Everything one scenario run contributes to the trajectory matrix:
/// accumulated counters, merged tick-unit latency histograms, the
/// concatenated lifecycle trace, and the request/token totals from the
/// responses themselves.
struct TrajectoryCell {
    snap: TrafficSnapshot,
    lat: LatencyReport,
    trace: Vec<TraceRecord>,
    ticks: u64,
    requests: u64,
    tokens: u64,
}

impl TrajectoryCell {
    fn new() -> TrajectoryCell {
        TrajectoryCell {
            snap: TrafficSnapshot::default(),
            lat: LatencyReport::default(),
            trace: Vec::new(),
            ticks: 0,
            requests: 0,
            tokens: 0,
        }
    }

    /// Fold one scheduler's observability state into the cell: drain
    /// its trace (loss-free or the gate fails), accumulate its
    /// snapshot, merge its latency histograms. Call once per scheduler,
    /// after it has drained — and for a scheduler about to be consumed
    /// by [`Scheduler::salvage`], call it *before* the salvage.
    fn absorb<E: Executor>(&mut self, s: &mut Scheduler<E>) {
        assert_eq!(s.trace_dropped(), 0, "trajectory: trace ring overflowed");
        self.trace.extend(s.take_trace());
        self.snap.accumulate(&s.metrics().traffic_snapshot());
        self.lat.merge(&s.latency_report());
        self.ticks += s.metrics().ticks;
    }

    fn note(&mut self, responses: &[Response]) {
        self.requests += responses.len() as u64;
        self.tokens += responses.iter().map(|r| r.tokens.len() as u64).sum::<u64>();
    }
}

/// Single-scheduler scenarios: submit everything, drain.
fn plain_cell(sc: &ServeScenario, vocab: usize) -> TrajectoryCell {
    let mut cell = TrajectoryCell::new();
    let mut s = Scheduler::with_path(MockEngine::new(), sc.policy.clone(), StatePath::Resident);
    for r in sc.requests(vocab) {
        s.submit(r).unwrap();
    }
    let resps = s.run_until_drained().unwrap();
    cell.note(&resps);
    cell.absorb(&mut s);
    cell
}

/// The sharding gate's migrate mode, reduced to its counters: two
/// shards, three hot requests moved cold mid-decode by state move.
fn skew_cell(sc: &ServeScenario, vocab: usize) -> TrajectoryCell {
    let mut cell = TrajectoryCell::new();
    let mut hot = Scheduler::with_path(MockEngine::new(), sc.policy.clone(), StatePath::Resident);
    hot.set_shard(0);
    let mut cold = Scheduler::with_path(MockEngine::new(), sc.policy.clone(), StatePath::Resident);
    cold.set_shard(1);
    for r in sc.requests(vocab) {
        if ServeScenario::SHARDED_HOT_IDS.contains(&r.id) {
            hot.submit(r).unwrap();
        } else {
            cold.submit(r).unwrap();
        }
    }
    let mut responses = Vec::new();
    let mut tick = 0u32;
    loop {
        let (a, pa) = hot.tick().unwrap();
        let (b, pb) = cold.tick().unwrap();
        responses.extend(a);
        responses.extend(b);
        tick += 1;
        assert!(tick < 10_000, "skew scenario did not drain");
        if tick == 14 {
            for seq in [1u64, 2, 3] {
                let p = hot.detach(seq).expect("hot request is decoding at the migrate tick");
                cold.attach(p).expect("well-formed packet attaches");
            }
        }
        if !pa && !pb && hot.pending() + cold.pending() == 0 {
            break;
        }
    }
    cell.note(&responses);
    cell.absorb(&mut hot);
    cell.absorb(&mut cold);
    cell
}

/// The snapshot gate's multi-turn flow: turn 1 stores each session's
/// state, turn 2 attaches it and prefills only its new tokens.
fn multi_turn_cell(sc: &ServeScenario, vocab: usize) -> TrajectoryCell {
    let mut cell = TrajectoryCell::new();
    let mut s = Scheduler::with_path(MockEngine::new(), sc.policy.clone(), StatePath::Resident);
    let turn1 = sc.requests(vocab);
    for r in &turn1 {
        s.submit_session(r.clone(), Some(r.id)).unwrap();
    }
    let mut t1 = s.run_until_drained().unwrap();
    t1.sort_by_key(|r| r.id);
    let turn2: Vec<Request> = turn1
        .iter()
        .zip(&t1)
        .map(|(r, resp)| Request {
            id: 1000 + r.id,
            prompt: ServeScenario::follow_up_prompt(
                &r.prompt,
                &resp.tokens,
                ServeScenario::MULTI_TURN_NEW_TOKENS,
                vocab,
            ),
            max_new_tokens: 8,
        })
        .collect();
    for (r2, r1) in turn2.iter().zip(&turn1) {
        s.submit_session(r2.clone(), Some(r1.id)).unwrap();
    }
    let t2 = s.run_until_drained().unwrap();
    cell.note(&t1);
    cell.note(&t2);
    cell.absorb(&mut s);
    cell
}

/// The snapshot gate's best-of-N flow: one shared prefill, N
/// copy-on-write forks, N candidates decoding from it.
fn best_of_n_cell(sc: &ServeScenario, vocab: usize) -> TrajectoryCell {
    let mut cell = TrajectoryCell::new();
    let parent_req = sc.requests(vocab).remove(0);
    let parent_session = 7u64;
    let n = ServeScenario::BEST_OF_N;
    let mut f = Scheduler::with_path(MockEngine::new(), sc.policy.clone(), StatePath::Resident);
    f.submit_session(parent_req.clone(), Some(parent_session)).unwrap();
    let shared = f.run_until_drained().unwrap();
    for i in 0..n as u64 {
        assert!(f.fork_session(parent_session, 100 + i), "fork {i} refused");
    }
    let children: Vec<Request> = (0..n as u64)
        .map(|i| {
            let mut p = parent_req.prompt.clone();
            p.push(shared[0].tokens[0]);
            Request { id: 10 + i, prompt: p, max_new_tokens: 8 }
        })
        .collect();
    for (i, r) in children.iter().enumerate() {
        f.submit_session(r.clone(), Some(100 + i as u64)).unwrap();
    }
    let outs = f.run_until_drained().unwrap();
    cell.note(&shared);
    cell.note(&outs);
    cell.absorb(&mut f);
    cell
}

/// The resilience gate's salvage path, reduced to its counters: build
/// the population to steady decode on a donor, migrate onto a faulty
/// shard, fault, salvage, finish on a healthy shard.
fn fault_storm_cell(sc: &ServeScenario, vocab: usize) -> TrajectoryCell {
    let mut cell = TrajectoryCell::new();
    let n = ServeScenario::FAULT_STORM_REQUESTS;
    let mut donor =
        Scheduler::with_path(MockEngine::new(), sc.policy.clone(), StatePath::Resident);
    donor.set_shard(0);
    for r in sc.requests(vocab) {
        donor.submit(r).unwrap();
    }
    let mut responses = Vec::new();
    for _ in 0..12 {
        let (done, _) = donor.tick().unwrap();
        responses.extend(done);
    }
    let tight = BatchPolicy { token_budget: 1, max_chunk_rows: 1, ..sc.policy.clone() };
    let inj = FaultInjector::new(FaultPlan::parse("nth:3").unwrap());
    let mut faulty = Scheduler::with_path(
        inj.wrap(MockEngine::new()).unwrap(),
        tight,
        StatePath::Resident,
    );
    faulty.set_shard(1);
    for seq in 0..n {
        let p = donor.detach(seq).expect("donor row is decoding after 12 ticks");
        faulty.attach(p).expect("well-formed packet attaches");
    }
    let mut faulted = false;
    for _ in 0..8 {
        match faulty.tick() {
            Ok((done, _)) => responses.extend(done),
            Err(_) => {
                faulted = true;
                break;
            }
        }
    }
    assert!(faulted, "nth:3 fires within eight serialized ticks");
    // Salvage consumes the scheduler — absorb its evidence first.
    cell.absorb(&mut faulty);
    let packets = faulty.salvage();
    let mut healthy =
        Scheduler::with_path(MockEngine::new(), sc.policy.clone(), StatePath::Resident);
    healthy.set_shard(2);
    for p in packets {
        if p.state_bytes() > 0 {
            healthy.attach(p).expect("salvaged state re-attaches");
        } else {
            healthy.attach_reprefill(p);
        }
    }
    responses.extend(healthy.run_until_drained().unwrap());
    cell.note(&responses);
    cell.absorb(&mut donor);
    cell.absorb(&mut healthy);
    cell
}

/// One scenario through the harness shape it exercises.
fn trajectory_cell(sc: &ServeScenario) -> TrajectoryCell {
    let vocab = MockEngine::new().manifest().vocab;
    match sc.name {
        "sharded_skew" => skew_cell(sc, vocab),
        "multi_turn" => multi_turn_cell(sc, vocab),
        "best_of_n" => best_of_n_cell(sc, vocab),
        "fault_storm" => fault_storm_cell(sc, vocab),
        _ => plain_cell(sc, vocab),
    }
}

/// One scenario's row in the trajectory matrix. Deterministic values
/// only — counters and tick-unit percentiles, never wall time.
fn trajectory_row(sc: &ServeScenario, cell: &TrajectoryCell) -> JsonValue {
    let spans = assemble_spans(&cell.trace);
    let mut row = JsonValue::obj();
    row.set("scenario", sc.name)
        .set("requests", cell.requests)
        .set("tokens", cell.tokens)
        .set("ticks", cell.ticks)
        .set("trace_events", cell.trace.len() as u64)
        .set("spans", spans.len() as u64)
        .set("device_calls", cell.snap.device_calls)
        .set("staged_bytes", cell.snap.bytes_gathered + cell.snap.bytes_scattered)
        .set("padded_rows", cell.snap.padded_rows)
        .set("migrations", cell.snap.migrations)
        .set("bytes_migrated", cell.snap.bytes_migrated)
        .set("reprefill_tokens", cell.snap.reprefill_tokens)
        .set("snapshot_hits", cell.snap.snapshot_hits)
        .set("snapshot_forks", cell.snap.snapshot_forks)
        .set("prefill_tokens_skipped", cell.snap.prefill_tokens_skipped)
        .set("plan_switches", cell.snap.plan_switches)
        .set("modeled_cycles", cell.snap.modeled_cycles)
        .set("requests_completed", cell.snap.requests_completed)
        .set("ttft_ticks_p50", cell.lat.ttft_ticks.percentile(0.50))
        .set("ttft_ticks_p99", cell.lat.ttft_ticks.percentile(0.99))
        .set("total_ticks_p50", cell.lat.total_ticks.percentile(0.50))
        .set("total_ticks_p99", cell.lat.total_ticks.percentile(0.99))
        .set("inter_token_ticks_p99", cell.lat.inter_token_ticks.percentile(0.99));
    row
}

/// The consolidated perf-trajectory artifact: all eight bundled
/// scenarios through one harness, one row per scenario with the full
/// deterministic counter set plus tick-unit latency percentiles from
/// the merged histograms. Per scenario the gate enforces:
///
/// * the reconciliation property — the drained trace accounts for the
///   accumulated counters exactly;
/// * exactly one assembled span per request, each with one terminal
///   event, and one tick-TTFT measurement per request;
/// * bit-identical rows on a second full run — the artifact holds no
///   wall-clock values, so a trajectory diff across commits is a
///   behaviour diff, never noise.
///
/// Writes `BENCH_trajectory.json`.
fn trajectory_gate() {
    println!("\n== perf trajectory: 8 scenarios x deterministic counters ==");
    let mut rows = JsonValue::Arr(vec![]);
    for sc in ServeScenario::all() {
        let cell = trajectory_cell(&sc);
        reconcile(&cell.trace, &cell.snap)
            .unwrap_or_else(|e| panic!("trajectory({}): reconciliation failed: {e}", sc.name));
        let spans = assemble_spans(&cell.trace);
        assert_eq!(
            spans.len() as u64,
            cell.requests,
            "{}: one span per request",
            sc.name
        );
        assert_eq!(
            cell.snap.requests_completed, cell.requests,
            "{}: every request completes",
            sc.name
        );
        assert_eq!(
            cell.lat.ttft_ticks.count(),
            cell.requests,
            "{}: one tick-TTFT measurement per request",
            sc.name
        );
        let row = trajectory_row(&sc, &cell);
        // The determinism proof: an identical re-run, identical row.
        let again = trajectory_row(&sc, &trajectory_cell(&sc));
        assert_eq!(
            row.to_string(),
            again.to_string(),
            "{}: trajectory row not deterministic across runs",
            sc.name
        );
        println!(
            "  {:<14} requests={:<2} ticks={:<4} events={:<5} ttft_ticks_p99={}",
            sc.name,
            cell.requests,
            cell.ticks,
            cell.trace.len(),
            cell.lat.ttft_ticks.percentile(0.99),
        );
        rows.push(row);
    }
    let mut gate = JsonValue::obj();
    gate.set("scenarios", 8u64)
        .set("reconciled", true)
        .set("spans_match_requests", true)
        .set("deterministic", true)
        .set("pass", true);
    let mut doc = JsonValue::obj();
    doc.set("bench", "trajectory").set("scenarios", rows).set("gate", gate);
    std::fs::write("BENCH_trajectory.json", doc.to_string())
        .expect("writing BENCH_trajectory.json");
    println!("wrote BENCH_trajectory.json (trajectory gate: PASS)");
}

// ---------------------------------------------------------------------------
// Frontend gate: SLO-aware admission under 10x overload + wire conformance
// ---------------------------------------------------------------------------

/// One overload run's evidence: sorted interactive TTFTs (scheduler
/// ticks, exact — extracted from trace spans, not histogram buckets),
/// per-class shed counts, and the work-tick total.
struct OverloadOutcome {
    /// Sorted Submit→FirstToken tick deltas for the interactive class.
    ttfts: Vec<u64>,
    shed: [u64; PRIORITY_CLASSES],
    work_ticks: u64,
    completed: u64,
}

/// Exact p99 over sorted per-request values (nearest-rank).
fn exact_p99(sorted: &[u64]) -> u64 {
    assert!(!sorted.is_empty(), "p99 of empty sample");
    let n = sorted.len();
    let rank = ((0.99 * n as f64).ceil() as usize).max(1) - 1;
    sorted[rank.min(n - 1)]
}

/// Drive the overload storm through a bare scheduler under one of
/// three admission regimes:
///
/// * `"base"` — interactive arrivals only (the unloaded baseline);
/// * `"fifo"` — everything admitted, no controller (the pathology);
/// * `"admission"` — the [`AdmissionController`] at the front door:
///   batch share 0.25 of each 12-tick window's 192-token capacity,
///   plus a 192-token queued-prompt backstop.
///
/// The submit loop runs on its own iteration clock (arrival ticks);
/// TTFT is measured on the scheduler's work-tick clock from the
/// drained trace — Submit and FirstToken stamps per span — so the
/// numbers are deterministic and exact.
fn overload_run(mode: &str) -> OverloadOutcome {
    let sc = ServeScenario::overload();
    let vocab = MockEngine::new().manifest().vocab;
    let arrivals = ServeScenario::overload_arrivals(vocab);
    let interactive: std::collections::BTreeSet<u64> = arrivals
        .iter()
        .filter(|a| a.class == Priority::Interactive.index())
        .map(|a| a.req.id)
        .collect();
    let window = ServeScenario::OVERLOAD_WINDOW_TICKS;
    let capacity = window * sc.policy.token_budget as u64;
    let mut s = Scheduler::with_path(MockEngine::new(), sc.policy.clone(), StatePath::Resident);
    let mut admission = AdmissionController::new(AdmissionConfig {
        window_ticks: window,
        token_budget: sc.policy.token_budget as u64,
        shares: [1.0, 1.0, 0.25],
        ttft_deadline_ticks: [u64::MAX; PRIORITY_CLASSES],
        max_queued_tokens: capacity,
        max_resident_bytes: u64::MAX,
    });
    let mut trace: Vec<TraceRecord> = Vec::new();
    let mut inflight: std::collections::HashMap<u64, u64> = std::collections::HashMap::new();
    let mut queued_tokens: u64 = 0;
    let mut completed: u64 = 0;
    let mut next = 0usize;
    let mut t: u64 = 0;
    loop {
        while next < arrivals.len() && arrivals[next].tick <= t {
            let a = &arrivals[next];
            next += 1;
            match mode {
                "base" if a.class != Priority::Interactive.index() => continue,
                "admission" => {
                    let class = Priority::from_index(a.class).expect("schedule class in range");
                    let load = LoadSignal {
                        queue_depth: s.waiting() as u64,
                        queued_prompt_tokens: queued_tokens,
                        running: s.running() as u64,
                        resident_state_bytes: 0,
                        budget_utilization: (s.running() as f64
                            / sc.policy.token_budget.max(1) as f64)
                            .min(1.0),
                    };
                    if admission.admit(class, a.req.prompt.len() as u64, t, &load).is_err() {
                        continue; // shed at the front door; the controller counted it
                    }
                }
                _ => {}
            }
            queued_tokens += a.req.prompt.len() as u64;
            inflight.insert(a.req.id, a.req.prompt.len() as u64);
            s.submit(a.req.clone()).unwrap();
        }
        let (done, _) = s.tick().unwrap();
        for r in &done {
            queued_tokens = queued_tokens.saturating_sub(inflight.remove(&r.id).unwrap_or(0));
            completed += 1;
        }
        if t % 64 == 0 {
            assert_eq!(s.trace_dropped(), 0, "frontend({mode}): trace ring overflowed");
            s.drain_trace_into(&mut trace);
        }
        if mode == "admission" && t > 0 && t % window == 0 {
            // Feed the deterministic tick histograms back as the
            // SLO-pressure signal (inert here — deadlines disabled —
            // but it keeps the gate on the same path the TCP loop uses).
            admission.note_latency(&s.latency_report());
        }
        t += 1;
        if next >= arrivals.len() && s.pending() == 0 {
            break;
        }
        assert!(t < 100_000, "frontend({mode}): overload run did not drain");
    }
    let work_ticks = s.tick_count();
    assert_eq!(s.trace_dropped(), 0, "frontend({mode}): trace ring overflowed");
    s.drain_trace_into(&mut trace);
    reconcile(&trace, &s.metrics().traffic_snapshot())
        .unwrap_or_else(|e| panic!("frontend({mode}): reconciliation failed: {e}"));
    let mut ttfts = Vec::new();
    for sp in assemble_spans(&trace) {
        if !interactive.contains(&sp.seq) {
            continue;
        }
        let stamp = |want: fn(&TraceEvent) -> bool| {
            sp.events.iter().find(|r| want(&r.event)).map(|r| r.tick)
        };
        let sub = stamp(|e| matches!(e, TraceEvent::Submit));
        let first = stamp(|e| matches!(e, TraceEvent::FirstToken));
        match (sub, first) {
            (Some(sub), Some(first)) => ttfts.push(first.saturating_sub(sub)),
            _ => panic!("frontend({mode}): interactive span {} missing Submit/FirstToken", sp.seq),
        }
    }
    ttfts.sort_unstable();
    OverloadOutcome { ttfts, shed: admission.shed(), work_ticks, completed }
}

/// Deterministic per-client request mix for the socket conformance
/// half: four interactive and three batch requests per client, ids
/// disjoint across clients.
fn client_requests(client: usize, vocab: usize) -> Vec<(Request, Priority)> {
    let v = vocab as i32;
    let base = 1_000 * client as u64;
    let mut reqs = Vec::new();
    for k in 0..4u64 {
        let id = base + k;
        reqs.push((
            Request {
                id,
                prompt: (0..(6 + k as i32 + client as i32))
                    .map(|x| (x * 7 + id as i32 + 1) % v)
                    .collect(),
                max_new_tokens: 3 + k as usize,
            },
            Priority::Interactive,
        ));
    }
    for k in 0..3u64 {
        let id = base + 100 + k;
        reqs.push((
            Request {
                id,
                prompt: (0..8).map(|x| (x * 5 + id as i32 + 2) % v).collect(),
                max_new_tokens: 4,
            },
            Priority::Batch,
        ));
    }
    reqs
}

/// The frontend gate, two halves:
///
/// **A — SLO under overload (deterministic, scheduler-direct).** The
/// shared `ServeScenario::overload` storm delivers ~2× each window's
/// token capacity (~10× the interactive class's own demand). Gate:
/// admission-controlled interactive p99 TTFT stays within 2× the
/// unloaded baseline while the FIFO no-admission run degrades ≥ 5×;
/// zero interactive sheds; every run reconciles trace-vs-counters
/// with zero dropped records; the admission run is bit-identical when
/// repeated.
///
/// **B — wire conformance (real TCP).** Three concurrent clients
/// against `frontend::serve` with batch share 0: every submitted id
/// gets exactly one terminal frame (shed batch requests get exactly
/// one `Error`, zero hung connections), interactive token streams are
/// bit-identical to in-process `serve_all`, and the server's trace
/// reconciles with shed requests as terminal `Failed` spans.
///
/// Writes `BENCH_frontend.json`.
fn frontend_gate() {
    println!("\n== frontend gate: admission under overload + wire conformance ==");
    let base = overload_run("base");
    let fifo = overload_run("fifo");
    let adm = overload_run("admission");
    let again = overload_run("admission");
    assert_eq!(adm.ttfts, again.ttfts, "frontend: admission run not deterministic");
    assert_eq!(adm.shed, again.shed, "frontend: shed counts not deterministic");

    let n_interactive = ServeScenario::OVERLOAD_WINDOWS;
    assert_eq!(base.ttfts.len() as u64, n_interactive, "baseline serves every interactive");
    assert_eq!(fifo.ttfts.len() as u64, n_interactive, "fifo serves every interactive");
    assert_eq!(adm.ttfts.len() as u64, n_interactive, "admission serves every interactive");
    let base_p99 = exact_p99(&base.ttfts);
    let fifo_p99 = exact_p99(&fifo.ttfts);
    let adm_p99 = exact_p99(&adm.ttfts);
    assert!(
        adm_p99 <= 2 * base_p99,
        "frontend: admission p99 {adm_p99} ticks > 2x unloaded baseline {base_p99}"
    );
    assert!(
        fifo_p99 >= 5 * base_p99,
        "frontend: fifo p99 {fifo_p99} ticks < 5x baseline {base_p99} — storm not overloading"
    );
    assert_eq!(adm.shed[Priority::Interactive.index()], 0, "interactive traffic never sheds");
    assert!(adm.shed[Priority::Batch.index()] > 0, "overload sheds batch traffic");
    assert_eq!(base.shed, [0; PRIORITY_CLASSES]);
    assert_eq!(fifo.shed, [0; PRIORITY_CLASSES]);
    println!(
        "  ttft_p99_ticks: base={base_p99} admission={adm_p99} fifo={fifo_p99}  \
         shed(batch)={} work_ticks: base={} admission={} fifo={}",
        adm.shed[Priority::Batch.index()],
        base.work_ticks,
        adm.work_ticks,
        fifo.work_ticks,
    );

    // --- Part B: wire conformance over real sockets ---
    let vocab = MockEngine::new().manifest().vocab;
    let n_clients = 3usize;
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().expect("local addr").to_string();
    let server = Server::start(vec![|| Ok(MockEngine::new())], BatchPolicy::default());
    let cfg = FrontendConfig {
        admission: AdmissionConfig {
            // Batch share 0: every batch submit sheds, deterministically.
            shares: [1.0, 1.0, 0.0],
            ..AdmissionConfig::default()
        },
        max_connections: Some(n_clients),
    };
    let srv = std::thread::spawn(move || serve(listener, server, cfg).expect("serve loop"));
    let clients: Vec<_> = (0..n_clients)
        .map(|c| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let reqs = client_requests(c, vocab);
                let replies = run_client(&addr, &reqs, Some(Duration::from_secs(60)))
                    .expect("client round trip");
                (reqs, replies)
            })
        })
        .collect();
    let mut all_interactive: Vec<Request> = Vec::new();
    let mut wire_tokens: std::collections::HashMap<u64, Vec<i32>> =
        std::collections::HashMap::new();
    let mut batch_sent = 0u64;
    let mut error_frames = 0u64;
    for handle in clients {
        let (reqs, replies) = handle.join().expect("client thread");
        assert_eq!(replies.len(), reqs.len(), "one terminal reply per submitted id");
        for ((req, prio), reply) in reqs.into_iter().zip(replies) {
            assert_eq!(req.id, reply.id, "replies in submission order");
            match prio {
                Priority::Batch => {
                    batch_sent += 1;
                    error_frames += 1;
                    let err = reply.error.as_deref().unwrap_or_else(|| {
                        panic!("batch request {} should shed, got tokens", req.id)
                    });
                    assert!(err.contains("shed"), "shed reason on the wire: {err}");
                    assert!(reply.tokens.is_empty(), "shed request streamed tokens");
                }
                _ => {
                    assert!(
                        reply.error.is_none(),
                        "interactive request {} failed: {:?}",
                        req.id,
                        reply.error
                    );
                    assert_eq!(reply.tokens.len(), req.max_new_tokens, "full stream delivered");
                    wire_tokens.insert(req.id, reply.tokens.clone());
                    all_interactive.push(req);
                }
            }
        }
    }
    let (mut server, stats) = srv.join().expect("serve thread");
    assert_eq!(stats.connections as usize, n_clients);
    assert_eq!(stats.shed, [0, 0, batch_sent], "every batch submit shed exactly once");
    assert_eq!(stats.errors, error_frames, "one Error frame per shed request");
    assert_eq!(
        stats.admitted[Priority::Interactive.index()] as usize,
        all_interactive.len(),
        "every interactive submit admitted"
    );

    // Shed requests reconcile as terminal Failed spans; served spans
    // complete; the trace accounts for the counters exactly.
    let events = server.trace();
    let traffic = server.traffic();
    assert_eq!(traffic.requests_shed, batch_sent);
    reconcile(&events, &traffic)
        .unwrap_or_else(|e| panic!("frontend(tcp): reconciliation failed: {e}"));
    let spans = assemble_spans(&events);
    assert_eq!(
        spans.len() as u64,
        batch_sent + all_interactive.len() as u64,
        "one span per submitted id, sheds included"
    );
    let failed = spans
        .iter()
        .filter(|sp| matches!(sp.terminal(), Some(TraceEvent::Failed)))
        .count() as u64;
    assert_eq!(failed, batch_sent, "every shed span terminates Failed");
    server.shutdown();

    // Bit-identical to in-process serve_all on the same requests.
    let (resps, _) = serve_all(
        || Ok(MockEngine::new()),
        BatchPolicy::default(),
        all_interactive.clone(),
    )
    .expect("serve_all baseline");
    assert_eq!(resps.len(), all_interactive.len());
    for r in &resps {
        assert_eq!(
            wire_tokens.get(&r.id),
            Some(&r.tokens),
            "request {}: socket stream diverged from serve_all",
            r.id
        );
    }
    println!(
        "  tcp: clients={n_clients} interactive={} batch_shed={batch_sent} \
         error_frames={error_frames} spans={} (bit-identical to serve_all)",
        all_interactive.len(),
        spans.len(),
    );

    let mut part_a = JsonValue::obj();
    part_a
        .set("base_p99_ttft_ticks", base_p99)
        .set("admission_p99_ttft_ticks", adm_p99)
        .set("fifo_p99_ttft_ticks", fifo_p99)
        .set("admission_bound", 2 * base_p99)
        .set("fifo_floor", 5 * base_p99)
        .set("interactive_requests", n_interactive)
        .set("batch_shed", adm.shed[Priority::Batch.index()])
        .set("interactive_shed", 0u64)
        .set("completed_admission", adm.completed)
        .set("completed_fifo", fifo.completed)
        .set("work_ticks_admission", adm.work_ticks)
        .set("work_ticks_fifo", fifo.work_ticks);
    let mut part_b = JsonValue::obj();
    part_b
        .set("clients", n_clients as u64)
        .set("interactive_served", all_interactive.len() as u64)
        .set("batch_shed", batch_sent)
        .set("error_frames", error_frames)
        .set("spans", spans.len() as u64)
        .set("bit_identical_to_serve_all", true);
    let mut gate = JsonValue::obj();
    gate.set("trace_dropped", 0u64)
        .set("reconciled", true)
        .set("deterministic", true)
        .set("one_terminal_per_request", true)
        .set("pass", true);
    let mut doc = JsonValue::obj();
    doc.set("bench", "frontend")
        .set("overload", part_a)
        .set("wire", part_b)
        .set("gate", gate);
    std::fs::write("BENCH_frontend.json", doc.to_string())
        .expect("writing BENCH_frontend.json");
    println!("wrote BENCH_frontend.json (frontend gate: PASS)");
}
