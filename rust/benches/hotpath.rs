//! `cargo bench` target: microbenchmarks of the library's hot paths —
//! the inputs to the §Perf optimization pass (EXPERIMENTS.md §Perf).
//!
//! * cascade construction + validation
//! * pairwise classification over all pairs
//! * greedy stitching (all variants)
//! * analytical evaluation (the DSE inner loop)
//! * pass analysis
//! * coordinator: state gather/scatter, mock decode step, full serve
//! * coordinator: long-prompt interference, chunked vs monolithic
//!   prefill (p99 TTFT and per-tick token cost under mixed traffic)
//! * util: JSON parse (manifest-sized doc)

use std::time::{Duration, Instant};

use mambalaya::arch::ArchSpec;
use mambalaya::bench_util::{bench_config, black_box, BenchResult};
use mambalaya::cascade::{mamba1, ModelConfig};
use mambalaya::coordinator::{
    serve_all, BatchPolicy, Request, Scheduler, StateManager, WorkloadGen,
};
use mambalaya::fusion::{classify_cascade, stitch, FusionVariant};
use mambalaya::model::{analyze_scope, evaluate, ExecOptions};
use mambalaya::runtime::{Executor, MockEngine};
use mambalaya::util::JsonValue;

fn b<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_config(name, 3, 20, Duration::from_millis(200), &mut f)
}

fn main() {
    let cfg = ModelConfig::mamba_2_8b();
    let arch = ArchSpec::mambalaya();
    let c = mamba1::build(&cfg, 16384, 64);
    let plans: Vec<_> =
        FusionVariant::all().iter().map(|&v| stitch(&c, v)).collect();
    let opts = ExecOptions::default();

    let mut results = Vec::new();
    results.push(b("cascade: build+validate mamba1/2.8b", || {
        let c = mamba1::build(&cfg, 16384, 64);
        black_box(c.validate().unwrap());
    }));
    results.push(b("fusion: classify all pairs", || {
        black_box(classify_cascade(&c));
    }));
    for v in FusionVariant::all() {
        results.push(b(&format!("fusion: stitch {}", v.name()), || {
            black_box(stitch(&c, v));
        }));
    }
    results.push(b("model: evaluate all 5 variants (DSE step)", || {
        for p in &plans {
            black_box(evaluate(&c, p, &arch, &opts));
        }
    }));
    results.push(b("model: pass analysis (full scope)", || {
        black_box(analyze_scope(&c, &(1..=24).collect::<Vec<_>>()));
    }));

    // Coordinator hot paths (mock engine → measures coordination
    // overhead, not model math).
    let mock = MockEngine::new();
    let m = mock.manifest().clone();
    let mut sm = StateManager::new(m.n_layer, m.d_inner * (m.d_conv - 1), m.d_inner * m.d_state);
    let conv = vec![0.5f32; 8 * m.conv_state_elems()];
    let ssm = vec![0.25f32; 8 * m.ssm_state_elems()];
    for s in 0..8u64 {
        sm.install_from_batch(s, 8, s as usize, &conv, &ssm);
    }
    let ids: Vec<u64> = (0..8).collect();
    results.push(b("coordinator: state gather+scatter b=8", || {
        let (c8, s8) = sm.gather(&ids, 8);
        sm.scatter(&ids, 8, &c8, &s8);
        black_box(());
    }));
    let probe = MockEngine::new();
    let (conv0, ssm0) = {
        let toks: Vec<i32> = (0..8 * m.prefill_len as i32).collect();
        let out = probe.prefill(8, &toks).unwrap();
        (out.conv_state, out.ssm_state)
    };
    results.push(b("coordinator: mock decode step b=8", || {
        black_box(probe.decode(8, &[1, 2, 3, 4, 5, 6, 7, 8], &conv0, &ssm0).unwrap());
    }));
    results.push(b("coordinator: serve 16 requests (mock)", || {
        let mut gen = WorkloadGen::new(3, m.vocab, m.prefill_len, 4, 4);
        let reqs = (0..16).map(|_| gen.next_request()).collect();
        black_box(serve_all(|| Ok(MockEngine::new()), BatchPolicy::default(), reqs).unwrap());
    }));

    // Mixed-traffic interference: six short-prompt sequences decode
    // while one 512-token prompt prefills. Chunked prefill bounds the
    // per-tick token cost to the budget, so the decoders' inter-token
    // gap stays bounded; monolithic prefill admits the whole prompt
    // into a single tick (max_tick_tokens ≥ 512) — the full-tick stall
    // the chunked scheduler exists to remove. TTFT p99 is dominated by
    // the long prompt in both modes; the stall shows up in the tick
    // span and the short requests' completion latency.
    println!("\n== mixed-traffic interference (mock engine) ==");
    let vocab = m.vocab;
    let mk_reqs = || {
        let mut reqs: Vec<Request> = (0..6)
            .map(|i| Request {
                id: i,
                prompt: vec![(i % 7) as i32 + 1; 4],
                max_new_tokens: 64,
            })
            .collect();
        reqs.push(Request {
            id: 99,
            prompt: (0..512).map(|x| x % vocab as i32).collect(),
            max_new_tokens: 4,
        });
        reqs
    };
    let chunked = BatchPolicy {
        chunk_tokens: 16,
        token_budget: 32,
        max_chunk_rows: 2,
        max_running: 8,
        decode_priority_threshold: 8,
    };
    let monolithic = BatchPolicy { chunk_tokens: 0, token_budget: 1 << 20, ..chunked.clone() };
    let mut tick_spans = Vec::new();
    for (name, policy) in [("chunked 16/32", chunked), ("monolithic", monolithic)] {
        let t0 = Instant::now();
        let mut s = Scheduler::new(MockEngine::new(), policy);
        for r in mk_reqs() {
            s.submit(r).unwrap();
        }
        let mut resps = s.run_until_drained().unwrap();
        resps.sort_by_key(|r| r.id);
        let short_p99: f64 = resps
            .iter()
            .filter(|r| r.id != 99)
            .map(|r| r.total)
            .fold(0.0, f64::max);
        let met = s.metrics();
        println!(
            "  {:<14} ticks={:<4} max_tick_tokens={:<4} ttft_p99={:>8.3}ms \
             short_latency_max={:>8.3}ms wall={:>9.3?}",
            name,
            met.ticks,
            met.max_tick_tokens,
            met.ttft_pct(0.99) * 1e3,
            short_p99 * 1e3,
            t0.elapsed()
        );
        tick_spans.push(met.max_tick_tokens);
    }
    // The acceptance invariant: decode never shares a tick with more
    // prefill work than the budget allows under chunking, while the
    // monolithic policy provably stalls a full tick on the long prompt.
    assert!(tick_spans[0] <= 32, "chunked tick span {} > budget", tick_spans[0]);
    assert!(tick_spans[1] >= 512, "monolithic did not admit the whole prompt");

    // Util.
    let manifest_text = std::fs::read_to_string("artifacts/manifest.json").unwrap_or_else(|_| {
        r#"{"a":[1,2,3],"b":{"c":1.5},"d":"xyz"}"#.repeat(1).to_string()
    });
    results.push(b("util: JSON parse (manifest)", || {
        black_box(JsonValue::parse(&manifest_text).unwrap());
    }));

    println!("== hot-path microbenchmarks ==");
    for r in &results {
        println!("{}", r.report());
    }
}
