//! `cargo bench` target: microbenchmarks of the library's hot paths —
//! the inputs to the §Perf optimization pass (EXPERIMENTS.md §Perf).
//!
//! * cascade construction + validation
//! * pairwise classification over all pairs
//! * greedy stitching (all variants)
//! * analytical evaluation (the DSE inner loop)
//! * pass analysis
//! * coordinator: state gather/scatter, mock decode step, full serve
//! * util: JSON parse (manifest-sized doc)

use std::time::Duration;

use mambalaya::arch::ArchSpec;
use mambalaya::bench_util::{bench_config, black_box, BenchResult};
use mambalaya::cascade::{mamba1, ModelConfig};
use mambalaya::coordinator::{serve_all, BatchPolicy, StateManager, WorkloadGen};
use mambalaya::fusion::{classify_cascade, stitch, FusionVariant};
use mambalaya::model::{analyze_scope, evaluate, ExecOptions};
use mambalaya::runtime::{Executor, MockEngine};
use mambalaya::util::JsonValue;

fn b<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    bench_config(name, 3, 20, Duration::from_millis(200), &mut f)
}

fn main() {
    let cfg = ModelConfig::mamba_2_8b();
    let arch = ArchSpec::mambalaya();
    let c = mamba1::build(&cfg, 16384, 64);
    let plans: Vec<_> =
        FusionVariant::all().iter().map(|&v| stitch(&c, v)).collect();
    let opts = ExecOptions::default();

    let mut results = Vec::new();
    results.push(b("cascade: build+validate mamba1/2.8b", || {
        let c = mamba1::build(&cfg, 16384, 64);
        black_box(c.validate().unwrap());
    }));
    results.push(b("fusion: classify all pairs", || {
        black_box(classify_cascade(&c));
    }));
    for v in FusionVariant::all() {
        results.push(b(&format!("fusion: stitch {}", v.name()), || {
            black_box(stitch(&c, v));
        }));
    }
    results.push(b("model: evaluate all 5 variants (DSE step)", || {
        for p in &plans {
            black_box(evaluate(&c, p, &arch, &opts));
        }
    }));
    results.push(b("model: pass analysis (full scope)", || {
        black_box(analyze_scope(&c, &(1..=24).collect::<Vec<_>>()));
    }));

    // Coordinator hot paths (mock engine → measures coordination
    // overhead, not model math).
    let mock = MockEngine::new();
    let m = mock.manifest().clone();
    let mut sm = StateManager::new(m.n_layer, m.d_inner * (m.d_conv - 1), m.d_inner * m.d_state);
    let conv = vec![0.5f32; 8 * m.conv_state_elems()];
    let ssm = vec![0.25f32; 8 * m.ssm_state_elems()];
    for s in 0..8u64 {
        sm.install_from_batch(s, 8, s as usize, &conv, &ssm);
    }
    let ids: Vec<u64> = (0..8).collect();
    results.push(b("coordinator: state gather+scatter b=8", || {
        let (c8, s8) = sm.gather(&ids, 8);
        sm.scatter(&ids, 8, &c8, &s8);
        black_box(());
    }));
    let probe = MockEngine::new();
    let (conv0, ssm0) = {
        let toks: Vec<i32> = (0..8 * m.prefill_len as i32).collect();
        let out = probe.prefill(8, &toks).unwrap();
        (out.conv_state, out.ssm_state)
    };
    results.push(b("coordinator: mock decode step b=8", || {
        black_box(probe.decode(8, &[1, 2, 3, 4, 5, 6, 7, 8], &conv0, &ssm0).unwrap());
    }));
    results.push(b("coordinator: serve 16 requests (mock)", || {
        let mut gen = WorkloadGen::new(3, m.vocab, m.prefill_len, 4, 4);
        let reqs = (0..16).map(|_| gen.next_request()).collect();
        black_box(serve_all(|| Ok(MockEngine::new()), BatchPolicy::default(), reqs).unwrap());
    }));

    // Util.
    let manifest_text = std::fs::read_to_string("artifacts/manifest.json").unwrap_or_else(|_| {
        r#"{"a":[1,2,3],"b":{"c":1.5},"d":"xyz"}"#.repeat(1).to_string()
    });
    results.push(b("util: JSON parse (manifest)", || {
        black_box(JsonValue::parse(&manifest_text).unwrap());
    }));

    println!("== hot-path microbenchmarks ==");
    for r in &results {
        println!("{}", r.report());
    }
}
