//! `cargo bench` target: ablation studies over the design choices
//! DESIGN.md calls out — the trade-off spaces the paper's §III-B
//! describes but does not plot:
//!
//! 1. buffer-capacity sweep through the mapper: "on-chip inter-Einsum
//!    storage reduces the available space for intra-Einsum storage";
//! 2. PE-array capacity sweep: where Mamba stops being compute-bound;
//! 3. state-size (N) sweep: how the SSM intermediates scale the fusion
//!    win;
//! 4. shared-input merging on/off: what the §IV pre-transform buys;
//! 5. per-tensor traffic attribution per variant (Figure 14 drill-down).

use mambalaya::arch::ArchSpec;
use mambalaya::cascade::{mamba1, ModelConfig};
use mambalaya::fusion::{stitch, FusionVariant};
use mambalaya::model::{evaluate, map_search, ExecOptions, MapperOptions};
use mambalaya::traffic::breakdown;

fn main() {
    let cfg = ModelConfig::mamba_370m();
    let arch = ArchSpec::mambalaya();
    let opts = ExecOptions::default();

    // 1. Buffer sweep: per-Einsum mapper traffic for the in-proj GEMM
    //    (#7) and the SSM readout (#21) as the buffer shrinks.
    println!("== ablation 1: mapper traffic vs buffer budget (I=4096) ==");
    let c = mamba1::build(&cfg, 4096, 1);
    for id in [7usize, 21] {
        let e = c.by_id(id).unwrap();
        print!("einsum #{id:<2} ({}):", e.name);
        for shift in [25u32, 23, 21, 19, 17] {
            let budget = 1u64 << shift;
            match map_search(e, &MapperOptions { buffer_budget: budget, ..Default::default() })
            {
                Some(m) => print!(
                    "  {}MiB→{:.2}×",
                    budget >> 20,
                    m.dram_bytes as f64
                        / mambalaya::model::unfused_traffic(&c, e).total() as f64
                ),
                None => print!("  {}MiB→∞", budget >> 20),
            }
        }
        println!();
    }

    // 2. PE sweep: fully-fused prefill latency as the 2D array scales.
    println!("\n== ablation 2: fully-fused prefill latency vs 2D-array size ==");
    let c = mamba1::build(&cfg, 16384, 64);
    let plan = stitch(&c, FusionVariant::FullyFused);
    for dim in [64u64, 128, 256, 512] {
        let mut a = arch.clone();
        a.pe_2d_rows = dim;
        a.pe_2d_cols = dim;
        let cost = evaluate(&c, &plan, &a, &opts);
        println!(
            "  {dim:>3}×{dim:<3} → {:>9.3} ms  (OI {:.0}, balance {:.0})",
            cost.latency_secs(&a) * 1e3,
            cost.intensity(),
            a.machine_balance()
        );
    }

    // 3. N sweep: the fusion win vs the SSM state size.
    println!("\n== ablation 3: unfused→fully-fused speedup vs d_state N ==");
    for n in [8u64, 16, 32, 64, 128] {
        let mut cfg_n = cfg.clone();
        cfg_n.d_state = n;
        let c = mamba1::build(&cfg_n, 4096, 16);
        let base = evaluate(&c, &stitch(&c, FusionVariant::Unfused), &arch, &opts);
        let ff = evaluate(&c, &stitch(&c, FusionVariant::FullyFused), &arch, &opts);
        println!("  N={n:<4} speedup {:.2}×", base.latency as f64 / ff.latency as f64);
    }

    // 4. Shared-input merging ablation: group counts with the merge
    //    pre-transform disabled (stitch the raw cascade per-Einsum).
    println!("\n== ablation 4: shared-input merging (paper §IV pre-transform) ==");
    {
        use mambalaya::fusion::merge::{find_shared_input_merges, to_units};
        let c = mamba1::build(&cfg, 1024, 1);
        let merges = find_shared_input_merges(&c);
        let merged_units = to_units(&c, &merges).len();
        let unmerged_units = to_units(&c, &[]).len();
        println!(
            "  stitching units: {merged_units} (merged) vs {unmerged_units} (unmerged); merge sets: {merges:?}"
        );
        for v in [FusionVariant::RIOnly, FusionVariant::RIRSbRSp] {
            let with = stitch(&c, v).groups.len();
            println!("  {v}: {with} groups with merging");
        }
    }

    // 5. Per-tensor traffic attribution (Figure 14 drill-down).
    println!("\n== ablation 5: hottest tensors per variant (I=4096, top 6) ==");
    let c = mamba1::build(&cfg, 4096, 1);
    for v in [FusionVariant::Unfused, FusionVariant::RIOnly, FusionVariant::FullyFused] {
        let bd = breakdown(&c, &stitch(&c, v));
        println!("--- {v} (total {} MiB)", bd.total() >> 20);
        print!("{}", bd.report(6));
    }
}
