//! Stub of the xla/PJRT binding surface used by `runtime::engine`.
//!
//! The offline build environment does not ship the PJRT runtime, so
//! this crate keeps the engine *compiling* while failing cleanly (and
//! loudly) at **load** time: `PjRtClient::cpu()` returns an error, so a
//! `MambaEngine` can never be constructed against the stub — the
//! coordinator falls back to `runtime::mock::MockEngine` (tests,
//! benches, `--mock` serving) which exercises the identical interface.
//!
//! To enable the real backend, replace the `xla = { path = ... }`
//! dependency in the root `Cargo.toml` with the real xla/PJRT binding
//! crate; `runtime::engine` is written against this exact surface.

use std::fmt;

/// Error type for every stubbed entry point.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: PJRT runtime unavailable (built against the vendored xla stub; \
             swap rust/vendor/xla for the real binding to enable it)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host-side literal (tokens, packed states). Construction and reshape
/// are pure bookkeeping and work; device execution does not.
#[derive(Debug, Clone)]
pub struct Literal {
    elems: usize,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(data: &[T]) -> Literal {
        Literal { elems: data.len(), dims: vec![data.len() as i64] }
    }

    /// Reshape, validating the element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.elems {
            return Err(Error(format!(
                "reshape: {} elements into {:?}",
                self.elems, dims
            )));
        }
        Ok(Literal { elems: self.elems, dims: dims.to_vec() })
    }

    /// Literal shape (diagnostics).
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Split a tuple literal — never reachable against the stub.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    /// Copy out as a host vector — never reachable against the stub.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module proto.
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation built from a proto.
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A device-resident buffer returned by execution.
#[derive(Debug, Clone)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable.
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given arguments; shape matches the real
    /// binding: one result vector per device, one buffer per output.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A PJRT client. The stub cannot construct one — `cpu()` errors, which
/// is the single choke point that keeps all other stubbed methods
/// unreachable in practice.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_path_fails_cleanly() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT runtime unavailable"));
    }

    #[test]
    fn literal_bookkeeping_works() {
        let l = Literal::vec1(&[1i32, 2, 3, 4, 5, 6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert!(l.reshape(&[4, 2]).is_err());
    }
}
