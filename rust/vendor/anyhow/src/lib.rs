//! Offline stand-in for the `anyhow` crate (the build environment has
//! no network, so third-party crates are vendored — see DESIGN.md §4).
//!
//! Implements the subset of the real API this repository uses:
//!
//! * [`Error`]: an opaque error value carrying a context chain;
//! * [`Result<T>`]: `std::result::Result<T, Error>`;
//! * [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros;
//! * `From<E: std::error::Error>` so `?` converts std errors.
//!
//! Formatting matches the real crate closely enough for our tests and
//! CLIs: `{}` prints the outermost message, `{:#}` prints the whole
//! chain separated by `: `, `{:?}` prints the chain as a "Caused by"
//! list. The structure (notably the `ext::StdError` indirection that
//! lets `Context` apply to both std errors and `Error` itself) mirrors
//! the real crate, whose coherence tricks are known to compile.

use std::fmt;

/// An error with a chain of context messages (outermost first).
pub struct Error {
    /// Context frames; `frames[0]` is the outermost message.
    frames: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { frames: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.frames.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.frames.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: full chain, outermost first, `: `-separated.
            write!(f, "{}", self.frames.join(": "))
        } else {
            write!(f, "{}", self.frames.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.frames.split_first() {
            None => Ok(()),
            Some((head, rest)) => {
                write!(f, "{head}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for (i, frame) in rest.iter().enumerate() {
                        write!(f, "\n    {i}: {frame}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

/// `anyhow::Result<T>` — the crate's error type by default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        // Preserve the std source chain as context frames.
        let mut frames = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            frames.push(s.to_string());
            src = s.source();
        }
        Error { frames }
    }
}

/// Private extension trait so `Context` has a single blanket impl that
/// covers both `Result<T, E: std::error::Error>` and `Result<T, Error>`
/// (the same structure the real crate uses).
mod ext {
    use super::Error;
    use std::fmt;

    pub trait StdError {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error;
    }

    impl<E> StdError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error {
            Error::from(self).context(context)
        }
    }

    impl StdError for Error {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error {
            self.context(context)
        }
    }
}

/// Attach context to errors (on `Result`) or turn `None` into an error
/// (on `Option`).
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::StdError + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_outermost_and_alternate_chain() {
        let e: Error = Result::<(), _>::Err(io_err())
            .with_context(|| "reading manifest".to_string())
            .unwrap_err();
        assert_eq!(e.to_string(), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: no such file");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let e: Error = Result::<(), Error>::Err(anyhow!("inner {}", 7))
            .context("outer")
            .unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 7");
        let o: Error = None::<u32>.context("missing").unwrap_err();
        assert_eq!(o.to_string(), "missing");
    }

    #[test]
    fn macros_in_fn_position() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(3).unwrap_err().to_string().contains("three"));
        assert!(f(11).unwrap_err().to_string().contains("too big"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::str::from_utf8(&[0xff])?;
            Ok(s.to_string())
        }
        assert!(f().is_err());
    }
}
