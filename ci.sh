#!/usr/bin/env bash
# CI gate for the Mambalaya reproduction.
#
#   ./ci.sh          # tier-1 gate + smoke-compile of benches/examples
#   ./ci.sh --fast   # tier-1 gate only
#
# Tier-1 (must stay green): cargo build --release && cargo test -q
# Smoke: benches and examples must *compile* (they are not run here —
# paper benches are long, and the PJRT example needs `make artifacts`).
# Python AOT-layer tests run only if a jax-capable interpreter exists,
# and are non-gating (the serving stack is pure Rust).

set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

# The fusion golden snapshot blesses itself on the very first run in a
# fresh checkout (the file cannot be generated without a toolchain, so
# it may not be in the tree yet). Re-run the golden test so this
# invocation always performs a real byte comparison, and insist the
# blessed file gets committed.
echo "== fusion golden: compare pass =="
cargo test -q --test fusion_golden
echo "== verify golden: compare pass =="
cargo test -q --test verify_golden
if [ -n "$(git status --porcelain -- rust/tests/golden 2>/dev/null)" ]; then
    echo "ERROR: rust/tests/golden changed/untracked — commit the blessed snapshot" >&2
    git status --short -- rust/tests/golden >&2
    exit 1
fi

# Static verifier: fusion legality, liveness-exact traffic cross-check,
# donation safety, and the source lint (wall-clock allowlist, hot-path
# unwrap ban, deprecated executor calls). Exits non-zero on any Error
# finding; the machine-readable report must exist for downstream tooling.
echo "== static verifier: mambalaya verify =="
cargo run --release --bin mambalaya -- verify --out VERIFY_report.json
if [ ! -s VERIFY_report.json ]; then
    echo "ERROR: VERIFY_report.json missing or empty" >&2
    exit 1
fi
echo "   VERIFY_report.json written"

if [[ "${1:-}" != "--fast" ]]; then
    echo "== smoke: benches + examples compile =="
    cargo check --release --benches --examples

    # Offline plan autotune: the coarse grid must sweep cleanly and
    # produce a loadable PlanTable artifact (the serving fast path).
    # The quick grid itself is pinned byte-for-byte by the golden
    # snapshot in rust/tests/golden/plan_table_quick.json.
    echo "== planner autotune: quick grid =="
    cargo run --release --bin mambalaya -- autotune --quick --out PLAN_TABLE.json
    if [ ! -s PLAN_TABLE.json ]; then
        echo "ERROR: PLAN_TABLE.json missing or empty" >&2
        exit 1
    fi
    echo "   PLAN_TABLE.json written"

    # Perf trajectory gates: the hotpath bench's --quick mode runs
    # (1) the deterministic mixed-traffic interference scenario and
    # asserts the resident state path moves >= 10x fewer state bytes
    # than the gather/scatter reference, (2) the adaptive-vs-static
    # plan-selection comparison on the bundled scenarios, asserting the
    # adaptive planner is never worse than the best static plan, its
    # predictor stays within 2x of the mock's modeled cost, and it
    # picks different plans for prefill-heavy vs decode-heavy traffic,
    # (3) the sharded-arena hot-skew scenario, asserting live
    # migration is token-identical to pinned serving, conserves the
    # global resident gauge, and beats the re-prefill fallback by >= 5x
    # (bytes_migrated vs reprefill_tokens * state_bytes_per_seq), and
    # (4) the engine-API gate on the chunk-heavy scenario, asserting a
    # caps-declared varlen engine launches exactly once per tick with
    # zero staged bytes while the caps-off decomposition pays at least
    # its lockstep floor (max(chunk) device calls per chunk tick) —
    # token outputs bit-identical either way, and (5) the snapshot
    # gate, asserting session follow-up turns prefill only their new
    # tokens (the skipped history beats the re-prefill fallback >= 5x
    # in state bytes) and best-of-N forks decode N candidates from one
    # prefill — both token-identical to full re-prefill, and (6) the
    # resilience gate on the fault_storm scenario, asserting a
    # fault-poisoned scheduler's salvage replays >= 5x fewer tokens
    # than reprefill-everything while recovering bit-identically, the
    # threaded server respawns a fail-once worker within its restart
    # cap (again bit-identical to fault-free), and a permanent fault
    # ends with exactly one terminal error Response per sink — never a
    # dropped channel, and (7) the trajectory gate, which serves all
    # eight bundled scenarios through one harness and writes the
    # consolidated scenario x counter matrix (plus tick-unit p50/p99
    # latency percentiles from the merged log2 histograms) to
    # BENCH_trajectory.json — every row reconciled against the
    # request-lifecycle trace and proven bit-identical across a re-run,
    # so a trajectory diff between commits is a behaviour diff, never
    # noise, and (8) the frontend gate, which drives the overload
    # storm (~10x the interactive class's own demand) and asserts
    # SLO-aware admission holds interactive p99 TTFT within 2x the
    # unloaded baseline while FIFO no-admission degrades >= 5x — zero
    # interactive sheds, every batch shed counted — then runs real
    # concurrent TCP clients through frontend::serve and asserts every
    # submitted id receives exactly one terminal frame over the wire
    # (shed requests get exactly one Error frame; zero hung
    # connections), with token streams bit-identical to in-process
    # serve_all and shed requests reconciling as terminal Failed
    # spans. (The runtime module also builds under
    # #![deny(missing_docs)], so the engine surface stays documented by
    # construction.)
    # Every gate additionally enforces the reconciliation property: the
    # drained lifecycle trace must account for the independent traffic
    # counters exactly (device calls, staged bytes, migrations,
    # snapshot hits, replayed tokens, completions — and exactly one
    # terminal event per request span).
    # All gates are on *counters* (same workload, same numbers, every
    # run), never on wall time; BENCH_hotpath.json, BENCH_planner.json,
    # BENCH_sharding.json, BENCH_engine_api.json, BENCH_snapshot.json,
    # BENCH_resilience.json, BENCH_trajectory.json and
    # BENCH_frontend.json record the trajectory.
    echo "== hotpath bench: quick counter gates (traffic + planner + sharding + engine API + snapshot + resilience + trajectory + frontend) =="
    cargo bench --bench hotpath -- --quick
    for f in BENCH_hotpath.json BENCH_planner.json BENCH_sharding.json BENCH_engine_api.json BENCH_snapshot.json BENCH_resilience.json BENCH_trajectory.json BENCH_frontend.json; do
        if [ ! -s "$f" ]; then
            echo "ERROR: $f missing or empty" >&2
            exit 1
        fi
    done
    echo "   BENCH_hotpath.json + BENCH_planner.json + BENCH_sharding.json + BENCH_engine_api.json + BENCH_snapshot.json + BENCH_resilience.json + BENCH_trajectory.json + BENCH_frontend.json written"

    if command -v python >/dev/null 2>&1 && python -c "import jax" >/dev/null 2>&1; then
        echo "== python AOT-layer tests (non-gating) =="
        python -m pytest -q python/tests || echo "WARNING: python tests failed (non-gating)"
    else
        echo "== python AOT-layer tests skipped (no jax) =="
    fi
fi

echo "ci.sh: all gates passed"
