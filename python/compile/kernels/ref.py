"""Pure-jnp oracle for the fused selective-scan (SSM) kernel.

This is the correctness reference for the Pallas kernel in
``selective_scan.py``: a direct ``lax.scan`` transcription of the paper's
SSM cascade (Einsums 16-23 of Figure 1):

    abar[l,d,n] = exp(delta[l,d] * A[d,n])            # 16  (A-bar)
    bx[l,d,n]   = delta[l,d] * B[l,n] * u[l,d]        # 17-18 (B-bar . x)
    h[l,d,n]    = abar[l,d,n]*h[l-1,d,n] + bx[l,d,n]  # 19-20
    s[l,d]      = sum_n C[l,n] * h[l,d,n]             # 21
    sd[l,d]     = s[l,d] + D[d]*u[l,d]                # 22
    y[l,d]      = sd[l,d] * silu(z[l,d])              # 23

All math runs in float32 for a stable oracle.
"""

import jax
import jax.numpy as jnp


def silu(x):
    return x * jax.nn.sigmoid(x)


def selective_scan_ref(u, delta, A, B, C, D, z, h0=None):
    """Reference fused selective scan for one sequence.

    Args:
      u:     [L, D]  SSM input (LEX).
      delta: [L, D]  softplus-ed timestep (Delta).
      A:     [D, N]  state matrix (negative for stability).
      B:     [L, N]  input projection (input-selective).
      C:     [L, N]  output projection (input-selective).
      D:     [D]     skip weight.
      z:     [L, D]  gate branch (RX).
      h0:    [D, N]  initial hidden state (zeros when None).

    Returns:
      (y, h_last): y [L, D] gated output, h_last [D, N] final state.
    """
    u, delta, B, C, z = (x.astype(jnp.float32) for x in (u, delta, B, C, z))
    A = A.astype(jnp.float32)
    D = D.astype(jnp.float32)
    L, d_inner = u.shape
    n = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((d_inner, n), jnp.float32)

    def step(h, inputs):
        u_l, dt_l, b_l, c_l = inputs
        abar = jnp.exp(dt_l[:, None] * A)            # [D, N]
        bx = dt_l[:, None] * b_l[None, :] * u_l[:, None]
        h = abar * h + bx                            # [D, N]
        s = h @ c_l                                  # [D]
        return h, s

    h_last, s_seq = jax.lax.scan(step, h0, (u, delta, B, C))
    sd = s_seq + D[None, :] * u
    y = sd * silu(z)
    return y, h_last


def selective_scan_ref_batched(u, delta, A, B, C, D, z, h0=None):
    """vmap of :func:`selective_scan_ref` over a leading batch dim."""
    if h0 is None:
        h0 = jnp.zeros((u.shape[0], u.shape[2], A.shape[1]), jnp.float32)
    fn = lambda u_, dt_, b_, c_, z_, h_: selective_scan_ref(u_, dt_, A, b_, c_, D, z_, h_)
    return jax.vmap(fn)(u, delta, B, C, z, h0)
