"""L1: the paper's compute hot-spot as a Pallas kernel.

The *fully-fused SSM region* (paper Einsums 16-23) in one kernel:
discretization (exp), the recurrent state update, the N-reduction
readout, the skip connection and the SiLU gate all happen per sequence
step with the hidden state resident in VMEM scratch - the paper's
"minimum intermediate tensor footprint" discipline realized on a
TPU-style memory hierarchy (DESIGN.md section "Hardware adaptation").

TPU adaptation notes
--------------------
* The GPU implementations the paper compares against tile the scan over
  threadblocks with the state in shared memory; here the analogue is a
  grid over ``D`` blocks with the ``(block_d, N)`` state tile in a VMEM
  scratch ref, sequential over ``L`` inside the kernel.
* The scan itself is VPU-shaped (elementwise/broadcast over N=16); the
  MXU only sees the surrounding projections, which stay in plain-XLA
  land (python/compile/model.py).
* ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute
  Mosaic custom-calls; numerics are validated through the interpreter
  and the same HLO runs from Rust.

VMEM budget per program instance (fp32):
  state tile  block_d*N
  + streams   L*(3*block_d + 2*N) read tiles
which for the AOT'd tiny model (block_d=64, N=16, L<=64) is ~64 KiB,
far under the ~16 MiB/core VMEM of a real TPU; on larger models the
same BlockSpec scales block_d down (see DESIGN.md "Perf").
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_kernel(u_ref, dt_ref, a_ref, b_ref, c_ref, dw_ref, z_ref, h0_ref,
                 y_ref, hout_ref):
    """One grid step: full L scan for one block of D channels. The state
    tile lives in the fori_loop carry (registers/VMEM under Mosaic; a
    numpy temporary under the interpreter)."""
    L = u_ref.shape[0]
    a = a_ref[...]                        # [block_d, N]
    dw = dw_ref[...]                      # [block_d]

    def body(l, h):
        u_l = u_ref[l, :]                 # [block_d]
        dt_l = dt_ref[l, :]               # [block_d]
        b_l = b_ref[l, :]                 # [N]
        c_l = c_ref[l, :]                 # [N]
        z_l = z_ref[l, :]                 # [block_d]
        abar = jnp.exp(dt_l[:, None] * a)                     # 16
        bx = (dt_l * u_l)[:, None] * b_l[None, :]             # 17-18
        h = abar * h + bx                                     # 19-20
        s = jnp.sum(h * c_l[None, :], axis=1)                 # 21
        sd = s + dw * u_l                                     # 22
        y_ref[l, :] = sd * (z_l * jax.nn.sigmoid(z_l))        # 23
        return h

    hout_ref[...] = jax.lax.fori_loop(0, L, body, h0_ref[...])


@functools.partial(jax.jit, static_argnames=("block_d",))
def selective_scan(u, delta, A, B, C, D, z, h0=None, *, block_d=None):
    """Fused selective scan via Pallas (interpret mode).

    Shapes as in :func:`..kernels.ref.selective_scan_ref`; returns
    ``(y [L, D], h_last [D, N])``.
    """
    u, delta, B, C, z = (x.astype(jnp.float32) for x in (u, delta, B, C, z))
    A = A.astype(jnp.float32)
    D = D.astype(jnp.float32)
    L, d_inner = u.shape
    n = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((d_inner, n), jnp.float32)
    if block_d is None:
        block_d = min(d_inner, 128)
    assert d_inner % block_d == 0, (d_inner, block_d)
    grid = (d_inner // block_d,)

    y, h_last = pl.pallas_call(
        _scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((L, block_d), lambda i: (0, i)),   # u
            pl.BlockSpec((L, block_d), lambda i: (0, i)),   # delta
            pl.BlockSpec((block_d, n), lambda i: (i, 0)),   # A
            pl.BlockSpec((L, n), lambda i: (0, 0)),         # B
            pl.BlockSpec((L, n), lambda i: (0, 0)),         # C
            pl.BlockSpec((block_d,), lambda i: (i,)),       # D skip
            pl.BlockSpec((L, block_d), lambda i: (0, i)),   # z
            pl.BlockSpec((block_d, n), lambda i: (i, 0)),   # h0
        ],
        out_specs=[
            pl.BlockSpec((L, block_d), lambda i: (0, i)),   # y
            pl.BlockSpec((block_d, n), lambda i: (i, 0)),   # h_last
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, d_inner), jnp.float32),
            jax.ShapeDtypeStruct((d_inner, n), jnp.float32),
        ],
        interpret=True,
    )(u, delta, A, B, C, D, z, h0)
    return y, h_last


def selective_scan_batched(u, delta, A, B, C, D, z, h0=None, *, block_d=None):
    """vmap over a leading batch dimension."""
    if h0 is None:
        h0 = jnp.zeros((u.shape[0], u.shape[2], A.shape[1]), jnp.float32)
    fn = lambda u_, dt_, b_, c_, z_, h_: selective_scan(
        u_, dt_, A, b_, c_, D, z_, h_, block_d=block_d)
    return jax.vmap(fn)(u, delta, B, C, z, h0)


def vmem_report(L, d_inner, n, block_d):
    """Estimated VMEM footprint (bytes, fp32) per program instance -
    used by DESIGN.md/EXPERIMENTS.md perf accounting."""
    state = block_d * n * 4
    streams = L * (3 * block_d + 2 * n) * 4 + block_d * n * 4 + block_d * 4
    out = L * block_d * 4 + block_d * n * 4
    return {"state": state, "streams": streams, "out": out,
            "total": state + streams + out}
