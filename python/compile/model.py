"""L2: the Mamba model in JAX (build-time only; never on the request
path).

The block follows the paper's Figure 1 cascade exactly (module comments
carry the Einsum numbers); the SSM hot-spot (Einsums 16-23) is the
Pallas kernel from ``kernels.selective_scan``, so it lowers into the
same HLO as the surrounding projections and ships to Rust as one
artifact.

Two entry points are AOT-lowered per batch size (see ``aot.py``):

* ``prefill(params, tokens[B, L])`` ->
      (logits[B, V], conv_state[layers, B, D, J-1], ssm_state[layers, B, D, N])
* ``decode_step(params, token[B], conv_state, ssm_state)`` ->
      (logits[B, V], conv_state', ssm_state')

The recurrent states are explicit inputs/outputs - they are the "H-state
cache" the Rust coordinator manages per sequence (Mamba's analogue of a
KV cache).
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.selective_scan import selective_scan_batched
from .kernels.ref import silu


@dataclass(frozen=True)
class MambaConfig:
    """Model dimensions (mirrors rust/src/cascade/config.rs)."""
    vocab: int = 256
    d_model: int = 64     # E
    n_layer: int = 2
    d_state: int = 16     # N
    d_conv: int = 4       # J
    expand: int = 2

    @property
    def d_inner(self):    # D
        return self.expand * self.d_model

    @property
    def dt_rank(self):    # R
        return max(1, self.d_model // 16)


def init_params(cfg: MambaConfig, seed: int = 0):
    """Deterministic synthetic weights (the modeling study needs shapes,
    not trained weights; serving correctness is vs the jnp oracle)."""
    key = jax.random.PRNGKey(seed)
    keys = iter(jax.random.split(key, 64))
    k = lambda: next(keys)
    E, D, N, R, J = (cfg.d_model, cfg.d_inner, cfg.d_state, cfg.dt_rank,
                     cfg.d_conv)
    init = lambda shape, scale: (jax.random.normal(k(), shape, jnp.float32)
                                 * scale)
    layers = []
    for _ in range(cfg.n_layer):
        layers.append({
            "norm_g": jnp.ones((E,), jnp.float32),
            "w_in_x": init((E, D), E ** -0.5),          # Einsum 7 (TX)
            "w_in_z": init((E, D), E ** -0.5),          # Einsum 8 (RX)
            "w_conv": init((D, J), 0.3),                # Einsum 9
            "b_conv": jnp.zeros((D,), jnp.float32),
            "w_xb": init((D, N), D ** -0.5),            # Einsum 11
            "w_xc": init((D, N), D ** -0.5),            # Einsum 12
            "w_xdt": init((D, R), D ** -0.5),           # Einsum 13
            "w_dt": init((R, D), R ** -0.5),            # Einsum 14
            "b_dt": jnp.full((D,), -2.0, jnp.float32),  # softplus ~ 0.12
            "a_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32),
                                      (D, 1))),         # Einsum 16 (A)
            "d_skip": jnp.ones((D,), jnp.float32),      # Einsum 22
            "w_out": init((D, E), D ** -0.5),           # Einsum 24
        })
    return {
        "embed": init((cfg.vocab, E), 0.02),
        "norm_f": jnp.ones((E,), jnp.float32),
        "layers": layers,
    }


def rmsnorm(x, g, eps=1e-5):
    """Einsums 2-6: SQ, NUM, ISR, NEX, GX."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)   # 2-3
    return x * jax.lax.rsqrt(var + eps) * g                 # 4-6


def causal_conv(x, w, b, state=None):
    """Einsum 9 (TTX): depthwise causal conv along L.

    x: [B, L, D]; w: [D, J]; state: [B, D, J-1] trailing context.
    Returns (y [B, L, D], new_state [B, D, J-1]).
    """
    B, L, D = x.shape
    J = w.shape[1]
    if state is None:
        state = jnp.zeros((B, D, J - 1), x.dtype)
    # Prepend the carried context, slide the window.
    ext = jnp.concatenate([jnp.swapaxes(state, 1, 2), x], axis=1)  # [B, L+J-1, D]
    y = jnp.zeros((B, L, D), x.dtype)
    for j in range(J):
        y = y + ext[:, j:j + L, :] * w[None, None, :, j]
    new_state = jnp.swapaxes(ext[:, L:, :], 1, 2)  # last J-1 inputs
    return y + b[None, None, :], new_state


def block(params, x, conv_state, ssm_state):
    """One Mamba block over [B, L, E]. Returns (y, conv_state', h')."""
    B, L, E = x.shape
    gx = rmsnorm(x, params["norm_g"])                        # 1-6
    tx = gx @ params["w_in_x"]                               # 7
    rx = gx @ params["w_in_z"]                               # 8
    ttx, conv_state = causal_conv(tx, params["w_conv"],
                                  params["b_conv"], conv_state)  # 9
    lex = silu(ttx)                                          # 10
    xb = lex @ params["w_xb"]                                # 11
    xc = lex @ params["w_xc"]                                # 12
    ttd = lex @ params["w_xdt"]                              # 13
    dt = ttd @ params["w_dt"] + params["b_dt"]               # 14
    dl = jax.nn.softplus(dt)                                 # 15
    a = -jnp.exp(params["a_log"])                            # A (negative)
    # Einsums 16-23, fused (Pallas kernel):
    y, h_last = selective_scan_batched(
        lex, dl, a, xb, xc, params["d_skip"], rx, ssm_state)
    out = y @ params["w_out"]                                # 24
    return x + out, conv_state, h_last


def forward(params, tokens, conv_states, ssm_states):
    """Full stack over [B, L] tokens. Returns (last-position logits,
    conv_states', ssm_states')."""
    x = params["embed"][tokens]                              # [B, L, E]
    new_conv, new_ssm = [], []
    for li, lp in enumerate(params["layers"]):
        x, cs, hs = block(lp, x, conv_states[li], ssm_states[li])
        new_conv.append(cs)
        new_ssm.append(hs)
    x = rmsnorm(x, params["norm_f"])
    logits = x[:, -1, :] @ params["embed"].T                 # tied head
    return logits, jnp.stack(new_conv), jnp.stack(new_ssm)


def zero_states(cfg: MambaConfig, batch: int):
    conv = jnp.zeros((cfg.n_layer, batch, cfg.d_inner, cfg.d_conv - 1),
                     jnp.float32)
    ssm = jnp.zeros((cfg.n_layer, batch, cfg.d_inner, cfg.d_state),
                    jnp.float32)
    return conv, ssm


def prefill(params, cfg: MambaConfig, tokens):
    """Prefill from empty state. tokens: [B, L] int32."""
    conv, ssm = zero_states(cfg, tokens.shape[0])
    return forward(params, tokens, conv, ssm)


def decode_step(params, token, conv_states, ssm_states):
    """One generation step. token: [B] int32."""
    return forward(params, token[:, None], conv_states, ssm_states)
