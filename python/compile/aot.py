"""AOT entry point: lower the L2 model to HLO *text* artifacts for the
Rust runtime.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Emitted into ``artifacts/``:
  mamba_tiny_prefill_b{B}.hlo.txt   B in {1,2,4}, L fixed
  mamba_tiny_decode_b{B}.hlo.txt    B in {1,2,4,8}
  scan_kernel.hlo.txt               standalone fused-scan kernel
  manifest.json                     shapes/dims for the Rust side
  golden.json                       input/output exemplars for the Rust
                                    runtime integration test

Run as ``python -m compile.aot --out-dir ../artifacts`` from python/
(the Makefile does this).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.selective_scan import selective_scan
from .model import MambaConfig, decode_step, init_params, prefill

PREFILL_BATCHES = (1, 2, 4)
DECODE_BATCHES = (1, 2, 4, 8)
PREFILL_LEN = 32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default HLO printer elides big
    # literals as ``constant({...})``, which would silently zero the
    # model weights after the text round-trip into the Rust runtime.
    return comp.as_hlo_text(True)


def lower_prefill(params, cfg, batch):
    fn = lambda tokens: prefill(params, cfg, tokens)
    spec = jax.ShapeDtypeStruct((batch, PREFILL_LEN), jnp.int32)
    return jax.jit(fn).lower(spec)


def lower_decode(params, cfg, batch):
    fn = lambda token, conv, ssm: decode_step(params, token, conv, ssm)
    tok = jax.ShapeDtypeStruct((batch,), jnp.int32)
    conv = jax.ShapeDtypeStruct(
        (cfg.n_layer, batch, cfg.d_inner, cfg.d_conv - 1), jnp.float32)
    ssm = jax.ShapeDtypeStruct(
        (cfg.n_layer, batch, cfg.d_inner, cfg.d_state), jnp.float32)
    return jax.jit(fn).lower(tok, conv, ssm)


def lower_scan_kernel(cfg, L=64):
    """Standalone fused-scan artifact (kernel-level Rust benching)."""
    D, N = cfg.d_inner, cfg.d_state
    f32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    fn = lambda u, dt, A, B, C, Dw, z: selective_scan(u, dt, A, B, C, Dw, z)
    return jax.jit(fn).lower(f32(L, D), f32(L, D), f32(D, N), f32(L, N),
                             f32(L, N), f32(D), f32(L, D))


def golden_vectors(params, cfg):
    """Exemplar I/O for the Rust runtime integration test."""
    rng = np.random.default_rng(1234)
    tokens = rng.integers(0, cfg.vocab, size=(2, PREFILL_LEN),
                          dtype=np.int32)
    logits, conv, ssm = prefill(params, cfg, jnp.asarray(tokens))
    tok2 = rng.integers(0, cfg.vocab, size=(2,), dtype=np.int32)
    logits2, conv2, ssm2 = decode_step(params, jnp.asarray(tok2), conv, ssm)
    return {
        "prefill_tokens": tokens.flatten().tolist(),
        "prefill_logits_sample": np.asarray(logits)[:, :8].flatten().tolist(),
        "prefill_logits_argmax": np.asarray(logits).argmax(-1).tolist(),
        "decode_token": tok2.tolist(),
        "decode_logits_sample": np.asarray(logits2)[:, :8].flatten().tolist(),
        "decode_logits_argmax": np.asarray(logits2).argmax(-1).tolist(),
        "ssm_state_sum": float(np.asarray(ssm2).sum()),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = MambaConfig()
    params = init_params(cfg, args.seed)

    written = {}
    for b in PREFILL_BATCHES:
        path = os.path.join(args.out_dir, f"mamba_tiny_prefill_b{b}.hlo.txt")
        text = to_hlo_text(lower_prefill(params, cfg, b))
        open(path, "w").write(text)
        written[f"prefill_b{b}"] = os.path.basename(path)
        print(f"wrote {path} ({len(text)} chars)")
    for b in DECODE_BATCHES:
        path = os.path.join(args.out_dir, f"mamba_tiny_decode_b{b}.hlo.txt")
        text = to_hlo_text(lower_decode(params, cfg, b))
        open(path, "w").write(text)
        written[f"decode_b{b}"] = os.path.basename(path)
        print(f"wrote {path} ({len(text)} chars)")
    path = os.path.join(args.out_dir, "scan_kernel.hlo.txt")
    text = to_hlo_text(lower_scan_kernel(cfg))
    open(path, "w").write(text)
    written["scan_kernel"] = os.path.basename(path)
    print(f"wrote {path} ({len(text)} chars)")

    manifest = {
        "model": "mamba-tiny",
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "d_inner": cfg.d_inner,
        "d_state": cfg.d_state,
        "d_conv": cfg.d_conv,
        "n_layer": cfg.n_layer,
        "prefill_len": PREFILL_LEN,
        "prefill_batches": list(PREFILL_BATCHES),
        "decode_batches": list(DECODE_BATCHES),
        "scan_kernel_len": 64,
        "seed": args.seed,
        "artifacts": written,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    with open(os.path.join(args.out_dir, "golden.json"), "w") as f:
        json.dump(golden_vectors(params, cfg), f, indent=2)
    print("wrote manifest.json, golden.json")


if __name__ == "__main__":
    main()
