"""L1 correctness: the Pallas fused-scan kernel vs the pure-jnp oracle.

This is the core numeric signal of the three-layer stack: the kernel
that ships (inside the AOT'd HLO) must match the reference cascade
bit-for-tolerance across shapes, dtypes, and state handoffs.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import selective_scan_ref, selective_scan_ref_batched
from compile.kernels.selective_scan import (
    selective_scan,
    selective_scan_batched,
    vmem_report,
)

RTOL = ATOL = 3e-5


def make_inputs(rng, L, D, N, dtype=np.float32):
    u, dt, z = (rng.standard_normal((L, D)).astype(dtype) for _ in range(3))
    A = -np.abs(rng.standard_normal((D, N))).astype(dtype)
    B, C = (rng.standard_normal((L, N)).astype(dtype) for _ in range(2))
    Dw = rng.standard_normal(D).astype(dtype)
    dt = np.log1p(np.exp(dt))  # positive timesteps
    return u, dt, A, B, C, Dw, z


def test_matches_ref_basic():
    rng = np.random.default_rng(0)
    args = make_inputs(rng, 32, 64, 16)
    y1, h1 = selective_scan(*args)
    y2, h2 = selective_scan_ref(*args)
    np.testing.assert_allclose(y1, y2, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(h1, h2, rtol=RTOL, atol=ATOL)


@settings(max_examples=25, deadline=None)
@given(
    L=st.integers(1, 48),
    log_d=st.integers(2, 7),
    log_n=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_shape_sweep(L, log_d, log_n, seed):
    """Hypothesis sweep over (L, D, N): the kernel must agree with the
    oracle for any power-of-two D (BlockSpec divisibility) and any N."""
    D, N = 2 ** log_d, 2 ** log_n
    rng = np.random.default_rng(seed)
    args = make_inputs(rng, L, D, N)
    block = min(D, 32)
    y1, h1 = selective_scan(*args, block_d=block)
    y2, h2 = selective_scan_ref(*args)
    np.testing.assert_allclose(y1, y2, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(h1, h2, rtol=RTOL, atol=ATOL)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), block_pow=st.integers(0, 6))
def test_block_size_invariance(seed, block_pow):
    """The D-tiling (BlockSpec) must not change the numerics."""
    rng = np.random.default_rng(seed)
    D = 64
    args = make_inputs(rng, 16, D, 8)
    block = 2 ** block_pow
    y1, h1 = selective_scan(*args, block_d=block)
    y2, h2 = selective_scan(*args, block_d=D)
    np.testing.assert_allclose(y1, y2, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(h1, h2, rtol=RTOL, atol=ATOL)


def test_dtype_inputs_f16_upcast():
    """fp16 inputs upcast to an fp32 datapath (paper: fp16 data, fp32
    accumulate)."""
    rng = np.random.default_rng(3)
    args = make_inputs(rng, 8, 16, 4, dtype=np.float16)
    y1, h1 = selective_scan(*args)
    y2, h2 = selective_scan_ref(*args)
    assert y1.dtype == jnp.float32
    np.testing.assert_allclose(y1, y2, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(h1, h2, rtol=1e-3, atol=1e-3)


def test_state_handoff_equals_full_scan():
    """Splitting a sequence and carrying h0 must equal one long scan -
    the invariant the serving coordinator relies on (prefill -> decode)."""
    rng = np.random.default_rng(7)
    L, D, N = 24, 32, 8
    u, dt, A, B, C, Dw, z = make_inputs(rng, L, D, N)
    y_full, h_full = selective_scan(u, dt, A, B, C, Dw, z)
    cut = 13
    y1, h1 = selective_scan(u[:cut], dt[:cut], A, B[:cut], C[:cut], Dw, z[:cut])
    y2, h2 = selective_scan(u[cut:], dt[cut:], A, B[cut:], C[cut:], Dw,
                            z[cut:], h0=h1)
    np.testing.assert_allclose(np.concatenate([y1, y2]), y_full,
                               rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(h2, h_full, rtol=RTOL, atol=ATOL)


def test_batched_matches_loop():
    rng = np.random.default_rng(11)
    Bsz, L, D, N = 3, 12, 32, 8
    u, dt, z = (rng.standard_normal((Bsz, L, D)).astype(np.float32)
                for _ in range(3))
    A = -np.abs(rng.standard_normal((D, N))).astype(np.float32)
    Bm, Cm = (rng.standard_normal((Bsz, L, N)).astype(np.float32)
              for _ in range(2))
    Dw = rng.standard_normal(D).astype(np.float32)
    dt = np.log1p(np.exp(dt))
    yb, hb = selective_scan_batched(u, dt, A, Bm, Cm, Dw, z)
    yr, hr = selective_scan_ref_batched(u, dt, A, Bm, Cm, Dw, z)
    np.testing.assert_allclose(yb, yr, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(hb, hr, rtol=RTOL, atol=ATOL)


def test_zero_delta_keeps_state():
    """Delta=0 => Abar=1, Bbar=0: the state passes through unchanged - a
    discretization sanity check."""
    rng = np.random.default_rng(5)
    L, D, N = 4, 8, 4
    u, _, A, B, C, Dw, z = make_inputs(rng, L, D, N)
    dt = np.zeros((L, D), np.float32)
    h0 = rng.standard_normal((D, N)).astype(np.float32)
    y, h = selective_scan(u, dt, A, B, C, Dw, z, h0=h0)
    np.testing.assert_allclose(h, h0, rtol=RTOL, atol=ATOL)


def test_vmem_report_scales():
    small = vmem_report(32, 128, 16, 32)
    big = vmem_report(32, 128, 16, 128)
    assert big["state"] == 4 * small["state"]
    assert big["total"] < (16 << 20), "must fit one TPU core's VMEM"


def test_unit_length_sequence():
    """L=1 (a decode step) is the degenerate scan."""
    rng = np.random.default_rng(9)
    args = make_inputs(rng, 1, 16, 8)
    y1, h1 = selective_scan(*args)
    y2, h2 = selective_scan_ref(*args)
    np.testing.assert_allclose(y1, y2, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(h1, h2, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("L,D,N", [(8, 16, 4), (16, 64, 16), (5, 32, 2)])
def test_parametrized_shapes(L, D, N):
    rng = np.random.default_rng(L * 100 + D + N)
    args = make_inputs(rng, L, D, N)
    block = min(D, 16)
    y1, h1 = selective_scan(*args, block_d=block)
    y2, h2 = selective_scan_ref(*args)
    np.testing.assert_allclose(y1, y2, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(h1, h2, rtol=RTOL, atol=ATOL)
