"""AOT path correctness: the HLO text we ship must reproduce the jitted
model's numerics when compiled and executed again, and must contain no
elided constants (which would silently zero the weights in Rust)."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
from compile import aot
from compile.model import MambaConfig, init_params, prefill, decode_step, zero_states

CFG = MambaConfig()
PARAMS = init_params(CFG, seed=0)


def test_hlo_has_no_elided_constants():
    text = aot.to_hlo_text(aot.lower_prefill(PARAMS, CFG, 1))
    assert "constant({...})" not in text
    assert "ENTRY" in text


def test_prefill_lowering_shapes():
    lowered = aot.lower_prefill(PARAMS, CFG, 2)
    out = lowered.out_info
    # (logits, conv_state, ssm_state)
    shapes = jax.tree_util.tree_leaves(out)
    assert shapes[0].shape == (2, CFG.vocab)


def test_decode_lowering_roundtrips_through_compile():
    """Compile the lowered decode step and compare against the direct
    call — catches lowering bugs without leaving python."""
    lowered = aot.lower_decode(PARAMS, CFG, 2)
    compiled = lowered.compile()
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(0, CFG.vocab, size=(2,), dtype=np.int32))
    conv, ssm = zero_states(CFG, 2)
    got = compiled(tok, conv, ssm)
    want = decode_step(PARAMS, tok, conv, ssm)
    for g, w in zip(jax.tree_util.tree_leaves(got), jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)


def test_golden_vectors_are_reproducible():
    g1 = aot.golden_vectors(PARAMS, CFG)
    g2 = aot.golden_vectors(PARAMS, CFG)
    assert g1["prefill_logits_argmax"] == g2["prefill_logits_argmax"]
    assert g1["decode_token"] == g2["decode_token"]


def test_manifest_matches_artifacts_if_built():
    """When artifacts/ exists (make artifacts), its manifest must agree
    with the current model config."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    path = os.path.join(root, "manifest.json")
    if not os.path.exists(path):
        return  # artifacts not built in this checkout
    m = json.load(open(path))
    assert m["d_model"] == CFG.d_model
    assert m["n_layer"] == CFG.n_layer
    assert m["vocab"] == CFG.vocab
    for name in m["artifacts"].values():
        assert os.path.exists(os.path.join(root, name)), name
