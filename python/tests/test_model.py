"""L2 correctness: model shapes, the prefill/decode state-handoff
invariant, and block-level numerics."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.model import (
    MambaConfig,
    causal_conv,
    decode_step,
    init_params,
    prefill,
    rmsnorm,
    zero_states,
)


CFG = MambaConfig()
PARAMS = init_params(CFG, seed=0)


def tokens(rng, b, l):
    return jnp.asarray(rng.integers(0, CFG.vocab, size=(b, l), dtype=np.int32))


def test_prefill_shapes():
    rng = np.random.default_rng(0)
    logits, conv, ssm = prefill(PARAMS, CFG, tokens(rng, 2, 16))
    assert logits.shape == (2, CFG.vocab)
    assert conv.shape == (CFG.n_layer, 2, CFG.d_inner, CFG.d_conv - 1)
    assert ssm.shape == (CFG.n_layer, 2, CFG.d_inner, CFG.d_state)
    assert bool(jnp.isfinite(logits).all())


def test_decode_shapes():
    conv, ssm = zero_states(CFG, 3)
    rng = np.random.default_rng(1)
    tok = jnp.asarray(rng.integers(0, CFG.vocab, size=(3,), dtype=np.int32))
    logits, conv2, ssm2 = decode_step(PARAMS, tok, conv, ssm)
    assert logits.shape == (3, CFG.vocab)
    assert conv2.shape == conv.shape and ssm2.shape == ssm.shape


@settings(max_examples=8, deadline=None)
@given(l=st.integers(2, 24), data=st.data(), seed=st.integers(0, 10**6))
def test_prefill_decode_consistency(l, data, seed):
    """prefill(t[:k]) + decode steps over t[k:] == prefill(t) - the
    recurrence carries exactly (the coordinator's core invariant)."""
    k = data.draw(st.integers(1, l - 1))
    rng = np.random.default_rng(seed)
    t = tokens(rng, 2, l)
    full_logits, _, full_ssm = prefill(PARAMS, CFG, t)
    logits, conv, ssm = prefill(PARAMS, CFG, t[:, :k])
    for i in range(k, l):
        logits, conv, ssm = decode_step(PARAMS, t[:, i], conv, ssm)
    np.testing.assert_allclose(logits, full_logits, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(ssm, full_ssm, rtol=2e-4, atol=2e-4)


def test_causal_conv_is_causal():
    """Changing input at position j must not affect outputs before j."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((1, 8, CFG.d_inner)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((CFG.d_inner, CFG.d_conv)),
                    jnp.float32)
    b = jnp.zeros((CFG.d_inner,), jnp.float32)
    y1, _ = causal_conv(x, w, b)
    x2 = x.at[:, 5, :].add(10.0)
    y2, _ = causal_conv(x2, w, b)
    np.testing.assert_allclose(y1[:, :5], y2[:, :5], rtol=1e-6, atol=1e-6)
    assert not np.allclose(y1[:, 5:], y2[:, 5:])


def test_causal_conv_state_handoff():
    """conv(x) == conv(x[:k]) ++ conv(x[k:], carried state)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 12, CFG.d_inner)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((CFG.d_inner, CFG.d_conv)),
                    jnp.float32)
    b = jnp.asarray(rng.standard_normal((CFG.d_inner,)), jnp.float32)
    y_full, s_full = causal_conv(x, w, b)
    y1, s1 = causal_conv(x[:, :7], w, b)
    y2, s2 = causal_conv(x[:, 7:], w, b, state=s1)
    np.testing.assert_allclose(np.concatenate([y1, y2], axis=1), y_full,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(s2, s_full, rtol=1e-5, atol=1e-5)


def test_rmsnorm_unit_scale():
    x = jnp.full((2, 4, 8), 3.0, jnp.float32)
    y = rmsnorm(x, jnp.ones((8,), jnp.float32))
    np.testing.assert_allclose(y, np.ones_like(y), rtol=1e-4, atol=1e-4)


def test_params_deterministic():
    p1 = init_params(CFG, seed=7)
    p2 = init_params(CFG, seed=7)
    np.testing.assert_array_equal(p1["embed"], p2["embed"])
    p3 = init_params(CFG, seed=8)
    assert not np.allclose(p1["embed"], p3["embed"])
